//! Simulated heterogeneous GPUs (DESIGN.md §3 substitution).
//!
//! The paper induces heterogeneity with a background "occupancy
//! program" on real 4090s; here a `SimGpu` wraps the shared PJRT CPU
//! substrate and imposes `1/(c_i · (1 - rho_i))` slowdown — either by
//! stretching real step durations (threaded mode) or analytically
//! through `CostModel` (timeline simulation). The cost model is
//! *calibrated from real measured PJRT step times* and includes the
//! fixed per-step overhead the paper observes in Fig. 9 ("single-step
//! delay no longer maintains a linear relationship with the patch
//! size due to some fixed overhead").

use std::time::{Duration, Instant};

use crate::config::DeviceConfig;
use crate::error::Result;
use crate::runtime::{DenoiserInputs, Runtime};
use crate::util::json::Value;
use crate::util::stats;

/// Affine per-step compute cost: seconds = c0 + c1 * rows (at unit
/// effective speed).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    pub fixed_s: f64,
    pub per_row_s: f64,
}

impl CostModel {
    /// A reasonable default when no calibration has run (roughly the
    /// shape measured on this substrate; benches always calibrate).
    pub fn uncalibrated() -> Self {
        CostModel { fixed_s: 4e-3, per_row_s: 1.2e-3 }
    }

    /// Step time on a device with effective speed `v` processing
    /// `rows` latent rows.
    pub fn step_time(&self, rows: usize, v: f64) -> f64 {
        assert!(v > 0.0);
        (self.fixed_s + self.per_row_s * rows as f64) / v
    }

    /// Fit from (rows, seconds) measurements by least squares.
    pub fn fit(samples: &[(usize, f64)]) -> Self {
        let xs: Vec<f64> = samples.iter().map(|&(r, _)| r as f64).collect();
        let ys: Vec<f64> = samples.iter().map(|&(_, s)| s).collect();
        let (a, b, _r2) = stats::linear_fit(&xs, &ys);
        CostModel { fixed_s: a.max(0.0), per_row_s: b.max(1e-9) }
    }

    /// Calibrate by timing the real denoiser artifacts at every AOT'd
    /// patch height. `reps` timed repetitions per height.
    pub fn calibrate(rt: &Runtime, reps: usize) -> Result<Self> {
        Self::calibrate_with(rt.manifest(), reps, |h, inp| {
            rt.denoise(h, inp)
        })
    }

    /// Backend-agnostic calibration: time `denoise` at every native
    /// patch height and fit the affine model. The PJRT and stub
    /// backends both route here, so every execution path shares one
    /// calibration contract.
    pub fn calibrate_with(
        manifest: &crate::runtime::artifacts::Manifest,
        reps: usize,
        mut denoise: impl FnMut(
            usize,
            &DenoiserInputs<'_>,
        ) -> Result<crate::runtime::DenoiserOutputs>,
    ) -> Result<Self> {
        let m = manifest.model.clone();
        let params = manifest.load_params()?;
        let heights = manifest.patch_heights.clone();
        let kv = crate::runtime::Tensor::zeros(&m.kv_shape());
        let cond = vec![0.1f32; m.dim];
        let mut samples = Vec::new();
        for &h in &heights {
            let x = crate::runtime::Tensor::zeros(&[h, m.latent_w, m.latent_c]);
            let inp = DenoiserInputs {
                params: &params,
                x_patch: &x,
                kv_stale: &kv,
                row_off: 0,
                t: 500.0,
                cond: &cond,
            };
            // Warm the executable then measure.
            denoise(h, &inp)?;
            let mut times = Vec::with_capacity(reps);
            for _ in 0..reps {
                let t0 = Instant::now();
                denoise(h, &inp)?;
                times.push(t0.elapsed().as_secs_f64());
            }
            samples.push((h, stats::median(&times)));
        }
        Ok(Self::fit(&samples))
    }

    pub fn to_json(&self) -> Value {
        let mut o = crate::util::json::Object::new();
        o.insert("fixed_s", Value::Num(self.fixed_s));
        o.insert("per_row_s", Value::Num(self.per_row_s));
        Value::Obj(o)
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        Ok(CostModel {
            fixed_s: v.get("fixed_s")?.as_f64()?,
            per_row_s: v.get("per_row_s")?.as_f64()?,
        })
    }
}

// --- Deterministic occupancy drift ---------------------------------

/// A deterministic per-device occupancy schedule keyed by the device's
/// *executed-step index within a request* — the offline stand-in for a
/// background job landing mid-denoise. Device `d`'s occupancy at its
/// `n`-th executed step is the value of the last breakpoint
/// `(from_step, occ)` with `from_step <= n`; devices without
/// breakpoints (or step indices before the first breakpoint) fall back
/// to their static config occupancy.
///
/// The schedule is pure data: executors never sleep on it. It drives
/// the *virtual* clocks — measured-step synthesis for in-request drift
/// detection and the drift-aware timeline simulation — so injected
/// drift is byte-reproducible on any build (the flake gate diffs
/// pinned stats JSON across two runs). It ships either inside a stub
/// manifest (`"drift"` table, see [`crate::runtime::stubgen`]) or via
/// the `STADI_DRIFT` environment variable, which overrides the
/// manifest.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct OccupancySchedule {
    /// Per device id: breakpoints `(from_step, occupancy)`, strictly
    /// increasing in `from_step`. Empty vec = no override.
    ramps: Vec<Vec<(usize, f64)>>,
}

/// Environment variable holding a drift spec (overrides the manifest):
/// per-device ramps separated by `;`, each ramp a comma-separated list
/// of `OCC@STEP` breakpoints — e.g. `"0@0;0@0,0.6@4"` keeps device 0
/// idle and lands a 60%-occupancy job on device 1 at its 4th step.
pub const DRIFT_ENV: &str = "STADI_DRIFT";

impl OccupancySchedule {
    pub fn new(ramps: Vec<Vec<(usize, f64)>>) -> Result<Self> {
        for (d, ramp) in ramps.iter().enumerate() {
            let mut prev: Option<usize> = None;
            for &(step, occ) in ramp {
                if !(0.0..1.0).contains(&occ) {
                    return Err(crate::error::Error::Config(format!(
                        "drift: device {d} occupancy {occ} outside [0, 1)"
                    )));
                }
                if matches!(prev, Some(p) if step <= p) {
                    return Err(crate::error::Error::Config(format!(
                        "drift: device {d} breakpoints must strictly \
                         increase (step {step})"
                    )));
                }
                prev = Some(step);
            }
        }
        Ok(OccupancySchedule { ramps })
    }

    pub fn num_devices(&self) -> usize {
        self.ramps.len()
    }

    /// True when no device carries any breakpoint.
    pub fn is_empty(&self) -> bool {
        self.ramps.iter().all(Vec::is_empty)
    }

    /// Occupancy override for `device` at its `step`-th executed step;
    /// `None` = no override (use the static config occupancy).
    pub fn occupancy(&self, device: usize, step: usize) -> Option<f64> {
        let ramp = self.ramps.get(device)?;
        ramp.iter()
            .take_while(|&&(from, _)| from <= step)
            .last()
            .map(|&(_, occ)| occ)
    }

    /// Effective speed of `gpu` at its `step`-th executed step under
    /// this schedule (its static speed when no breakpoint applies).
    pub fn speed_at(&self, gpu: &SimGpu, global_id: usize, step: usize) -> f64 {
        match self.occupancy(global_id, step) {
            Some(occ) => gpu.config.capability * (1.0 - occ),
            None => gpu.effective_speed(),
        }
    }

    /// Parse the `STADI_DRIFT` spec format (see [`DRIFT_ENV`]).
    pub fn parse(spec: &str) -> Result<Self> {
        let mut ramps = Vec::new();
        for seg in spec.split(';') {
            let mut ramp = Vec::new();
            for part in seg.split(',').filter(|s| !s.trim().is_empty()) {
                let (occ, step) =
                    part.trim().split_once('@').ok_or_else(|| {
                        crate::error::Error::Config(format!(
                            "drift: bad breakpoint {part:?} (want OCC@STEP)"
                        ))
                    })?;
                let occ: f64 = occ.trim().parse().map_err(|_| {
                    crate::error::Error::Config(format!(
                        "drift: bad occupancy {occ:?}"
                    ))
                })?;
                let step: usize = step.trim().parse().map_err(|_| {
                    crate::error::Error::Config(format!(
                        "drift: bad step {step:?}"
                    ))
                })?;
                ramp.push((step, occ));
            }
            ramps.push(ramp);
        }
        Self::new(ramps)
    }

    /// Read the schedule from [`DRIFT_ENV`] if set.
    pub fn from_env() -> Result<Option<Self>> {
        match std::env::var(DRIFT_ENV) {
            Ok(s) if !s.trim().is_empty() => Ok(Some(Self::parse(&s)?)),
            _ => Ok(None),
        }
    }

    /// Manifest encoding: an array per device of `[from_step, occ]`
    /// pairs.
    pub fn to_json(&self) -> Value {
        Value::Arr(
            self.ramps
                .iter()
                .map(|ramp| {
                    Value::Arr(
                        ramp.iter()
                            .map(|&(s, o)| {
                                Value::Arr(vec![
                                    Value::Num(s as f64),
                                    Value::Num(o),
                                ])
                            })
                            .collect(),
                    )
                })
                .collect(),
        )
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let mut ramps = Vec::new();
        for ramp in v.as_arr()? {
            let mut out = Vec::new();
            for bp in ramp.as_arr()? {
                let pair = bp.as_arr()?;
                if pair.len() != 2 {
                    return Err(crate::error::Error::Config(
                        "drift: breakpoint must be [step, occ]".into(),
                    ));
                }
                out.push((pair[0].as_usize()?, pair[1].as_f64()?));
            }
            ramps.push(out);
        }
        Self::new(ramps)
    }
}

/// One simulated GPU.
#[derive(Debug, Clone)]
pub struct SimGpu {
    pub id: usize,
    pub config: DeviceConfig,
    pub cost: CostModel,
}

impl SimGpu {
    pub fn new(id: usize, config: DeviceConfig, cost: CostModel) -> Self {
        SimGpu { id, config, cost }
    }

    pub fn effective_speed(&self) -> f64 {
        self.config.effective_speed()
    }

    /// Analytic step duration (timeline simulation path).
    pub fn step_time(&self, rows: usize) -> f64 {
        self.cost.step_time(rows, self.effective_speed())
    }

    /// Threaded-mode heterogeneity: given that the shared substrate
    /// just spent `real_s` computing `rows` rows, sleep the remainder
    /// so the step takes what this device would take. (The occupancy
    /// program's effect, imposed deterministically.)
    pub fn stretch_step(&self, rows: usize, real_s: f64) {
        let target = self.step_time(rows);
        if target > real_s {
            std::thread::sleep(Duration::from_secs_f64(target - real_s));
        }
    }
}

/// Build the simulated cluster from config + one shared cost model.
pub fn build_cluster(devices: &[DeviceConfig], cost: CostModel) -> Vec<SimGpu> {
    devices
        .iter()
        .enumerate()
        .map(|(i, d)| SimGpu::new(i, d.clone(), cost))
        .collect()
}

/// Clone a cluster with each device's row-proportional step cost
/// scaled by `ratio` — the tokens-per-row ratio of a non-native
/// canvas width relative to the width the cost model was calibrated
/// on. Both the latency predictor and session timelines use this one
/// helper, so admission decisions and reported numbers cannot drift
/// apart. Ratio 1.0 is a float-identical identity.
pub fn scale_cluster_per_row(cluster: &[SimGpu], ratio: f64) -> Vec<SimGpu> {
    cluster
        .iter()
        .map(|g| {
            let mut g = g.clone();
            g.cost.per_row_s *= ratio;
            g
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_time_scales_with_occupancy() {
        let cost = CostModel { fixed_s: 0.01, per_row_s: 0.001 };
        let idle = SimGpu::new(
            0,
            DeviceConfig::new("a", 1.0, 0.0),
            cost,
        );
        let busy = SimGpu::new(
            1,
            DeviceConfig::new("b", 1.0, 0.6),
            cost,
        );
        let t_idle = idle.step_time(16);
        let t_busy = busy.step_time(16);
        assert!((t_idle - 0.026).abs() < 1e-12);
        assert!((t_busy - 0.026 / 0.4).abs() < 1e-12);
    }

    #[test]
    fn fit_recovers_affine_cost() {
        let truth = CostModel { fixed_s: 0.004, per_row_s: 0.0012 };
        let samples: Vec<(usize, f64)> = [4usize, 8, 16, 24, 32]
            .iter()
            .map(|&r| (r, truth.step_time(r, 1.0)))
            .collect();
        let fit = CostModel::fit(&samples);
        assert!((fit.fixed_s - truth.fixed_s).abs() < 1e-9);
        assert!((fit.per_row_s - truth.per_row_s).abs() < 1e-9);
    }

    #[test]
    fn json_roundtrip() {
        let c = CostModel { fixed_s: 0.002, per_row_s: 0.0005 };
        let back = CostModel::from_json(&c.to_json()).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn occupancy_schedule_lookup_and_fallback() {
        let s = OccupancySchedule::parse("0@0;0@0,0.6@4").unwrap();
        assert_eq!(s.num_devices(), 2);
        assert_eq!(s.occupancy(0, 0), Some(0.0));
        assert_eq!(s.occupancy(0, 99), Some(0.0));
        assert_eq!(s.occupancy(1, 3), Some(0.0));
        assert_eq!(s.occupancy(1, 4), Some(0.6));
        assert_eq!(s.occupancy(1, 100), Some(0.6));
        // Devices beyond the spec, and steps before the first
        // breakpoint, fall back to the static config.
        assert_eq!(s.occupancy(2, 0), None);
        let late = OccupancySchedule::parse(";0.5@8").unwrap();
        assert_eq!(late.occupancy(0, 3), None);
        assert_eq!(late.occupancy(1, 7), None);
        assert_eq!(late.occupancy(1, 8), Some(0.5));
        // speed_at: override replaces the config occupancy entirely.
        let gpu = SimGpu::new(
            1,
            DeviceConfig::new("g", 0.8, 0.25),
            CostModel::uncalibrated(),
        );
        assert!((s.speed_at(&gpu, 1, 2) - 0.8).abs() < 1e-12);
        assert!((s.speed_at(&gpu, 1, 9) - 0.8 * 0.4).abs() < 1e-12);
        assert!((s.speed_at(&gpu, 2, 9) - 0.8 * 0.75).abs() < 1e-12);
    }

    #[test]
    fn occupancy_schedule_rejects_bad_specs() {
        assert!(OccupancySchedule::parse("1.0@0").is_err()); // occ >= 1
        assert!(OccupancySchedule::parse("0.5@4,0.6@4").is_err()); // order
        assert!(OccupancySchedule::parse("0.5@4,0.6@2").is_err());
        assert!(OccupancySchedule::parse("nope").is_err());
        assert!(OccupancySchedule::parse("0.5@x").is_err());
        // Empty segments are fine (device without override).
        let s = OccupancySchedule::parse(";").unwrap();
        assert!(s.is_empty());
        assert_eq!(s.num_devices(), 2);
    }

    #[test]
    fn occupancy_schedule_json_roundtrip() {
        let s = OccupancySchedule::parse("0@0,0.3@2;0.7@5").unwrap();
        let back = OccupancySchedule::from_json(&s.to_json()).unwrap();
        assert_eq!(s, back);
        // Malformed breakpoints are typed errors.
        let bad = crate::util::json::parse("[[[0]]]").unwrap();
        assert!(OccupancySchedule::from_json(&bad).is_err());
    }

    #[test]
    fn drift_env_parses_and_absence_is_none() {
        // No env mutation (tests run concurrently): consistency with
        // whatever the environment actually says.
        match std::env::var(DRIFT_ENV) {
            Ok(s) if !s.trim().is_empty() => {
                let got = OccupancySchedule::from_env().unwrap().unwrap();
                assert_eq!(got, OccupancySchedule::parse(&s).unwrap());
            }
            _ => assert!(OccupancySchedule::from_env().unwrap().is_none()),
        }
    }

    #[test]
    fn cluster_preserves_order_and_ids() {
        let devs = vec![
            DeviceConfig::new("x", 1.0, 0.0),
            DeviceConfig::new("y", 0.9, 0.2),
        ];
        let cluster = build_cluster(&devs, CostModel::uncalibrated());
        assert_eq!(cluster[0].id, 0);
        assert_eq!(cluster[1].config.name, "y");
        assert!(cluster[1].effective_speed() < 0.73);
    }
}
