//! Simulated heterogeneous GPUs (DESIGN.md §3 substitution).
//!
//! The paper induces heterogeneity with a background "occupancy
//! program" on real 4090s; here a `SimGpu` wraps the shared PJRT CPU
//! substrate and imposes `1/(c_i · (1 - rho_i))` slowdown — either by
//! stretching real step durations (threaded mode) or analytically
//! through `CostModel` (timeline simulation). The cost model is
//! *calibrated from real measured PJRT step times* and includes the
//! fixed per-step overhead the paper observes in Fig. 9 ("single-step
//! delay no longer maintains a linear relationship with the patch
//! size due to some fixed overhead").

use std::time::{Duration, Instant};

use crate::config::DeviceConfig;
use crate::error::Result;
use crate::runtime::{DenoiserInputs, Runtime};
use crate::util::json::Value;
use crate::util::stats;

/// Affine per-step compute cost: seconds = c0 + c1 * rows (at unit
/// effective speed).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    pub fixed_s: f64,
    pub per_row_s: f64,
}

impl CostModel {
    /// A reasonable default when no calibration has run (roughly the
    /// shape measured on this substrate; benches always calibrate).
    pub fn uncalibrated() -> Self {
        CostModel { fixed_s: 4e-3, per_row_s: 1.2e-3 }
    }

    /// Step time on a device with effective speed `v` processing
    /// `rows` latent rows.
    pub fn step_time(&self, rows: usize, v: f64) -> f64 {
        assert!(v > 0.0);
        (self.fixed_s + self.per_row_s * rows as f64) / v
    }

    /// Fit from (rows, seconds) measurements by least squares.
    pub fn fit(samples: &[(usize, f64)]) -> Self {
        let xs: Vec<f64> = samples.iter().map(|&(r, _)| r as f64).collect();
        let ys: Vec<f64> = samples.iter().map(|&(_, s)| s).collect();
        let (a, b, _r2) = stats::linear_fit(&xs, &ys);
        CostModel { fixed_s: a.max(0.0), per_row_s: b.max(1e-9) }
    }

    /// Calibrate by timing the real denoiser artifacts at every AOT'd
    /// patch height. `reps` timed repetitions per height.
    pub fn calibrate(rt: &Runtime, reps: usize) -> Result<Self> {
        Self::calibrate_with(rt.manifest(), reps, |h, inp| {
            rt.denoise(h, inp)
        })
    }

    /// Backend-agnostic calibration: time `denoise` at every native
    /// patch height and fit the affine model. The PJRT and stub
    /// backends both route here, so every execution path shares one
    /// calibration contract.
    pub fn calibrate_with(
        manifest: &crate::runtime::artifacts::Manifest,
        reps: usize,
        mut denoise: impl FnMut(
            usize,
            &DenoiserInputs<'_>,
        ) -> Result<crate::runtime::DenoiserOutputs>,
    ) -> Result<Self> {
        let m = manifest.model.clone();
        let params = manifest.load_params()?;
        let heights = manifest.patch_heights.clone();
        let kv = crate::runtime::Tensor::zeros(&m.kv_shape());
        let cond = vec![0.1f32; m.dim];
        let mut samples = Vec::new();
        for &h in &heights {
            let x = crate::runtime::Tensor::zeros(&[h, m.latent_w, m.latent_c]);
            let inp = DenoiserInputs {
                params: &params,
                x_patch: &x,
                kv_stale: &kv,
                row_off: 0,
                t: 500.0,
                cond: &cond,
            };
            // Warm the executable then measure.
            denoise(h, &inp)?;
            let mut times = Vec::with_capacity(reps);
            for _ in 0..reps {
                let t0 = Instant::now();
                denoise(h, &inp)?;
                times.push(t0.elapsed().as_secs_f64());
            }
            samples.push((h, stats::median(&times)));
        }
        Ok(Self::fit(&samples))
    }

    pub fn to_json(&self) -> Value {
        let mut o = crate::util::json::Object::new();
        o.insert("fixed_s", Value::Num(self.fixed_s));
        o.insert("per_row_s", Value::Num(self.per_row_s));
        Value::Obj(o)
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        Ok(CostModel {
            fixed_s: v.get("fixed_s")?.as_f64()?,
            per_row_s: v.get("per_row_s")?.as_f64()?,
        })
    }
}

/// One simulated GPU.
#[derive(Debug, Clone)]
pub struct SimGpu {
    pub id: usize,
    pub config: DeviceConfig,
    pub cost: CostModel,
}

impl SimGpu {
    pub fn new(id: usize, config: DeviceConfig, cost: CostModel) -> Self {
        SimGpu { id, config, cost }
    }

    pub fn effective_speed(&self) -> f64 {
        self.config.effective_speed()
    }

    /// Analytic step duration (timeline simulation path).
    pub fn step_time(&self, rows: usize) -> f64 {
        self.cost.step_time(rows, self.effective_speed())
    }

    /// Threaded-mode heterogeneity: given that the shared substrate
    /// just spent `real_s` computing `rows` rows, sleep the remainder
    /// so the step takes what this device would take. (The occupancy
    /// program's effect, imposed deterministically.)
    pub fn stretch_step(&self, rows: usize, real_s: f64) {
        let target = self.step_time(rows);
        if target > real_s {
            std::thread::sleep(Duration::from_secs_f64(target - real_s));
        }
    }
}

/// Build the simulated cluster from config + one shared cost model.
pub fn build_cluster(devices: &[DeviceConfig], cost: CostModel) -> Vec<SimGpu> {
    devices
        .iter()
        .enumerate()
        .map(|(i, d)| SimGpu::new(i, d.clone(), cost))
        .collect()
}

/// Clone a cluster with each device's row-proportional step cost
/// scaled by `ratio` — the tokens-per-row ratio of a non-native
/// canvas width relative to the width the cost model was calibrated
/// on. Both the latency predictor and session timelines use this one
/// helper, so admission decisions and reported numbers cannot drift
/// apart. Ratio 1.0 is a float-identical identity.
pub fn scale_cluster_per_row(cluster: &[SimGpu], ratio: f64) -> Vec<SimGpu> {
    cluster
        .iter()
        .map(|g| {
            let mut g = g.clone();
            g.cost.per_row_s *= ratio;
            g
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_time_scales_with_occupancy() {
        let cost = CostModel { fixed_s: 0.01, per_row_s: 0.001 };
        let idle = SimGpu::new(
            0,
            DeviceConfig::new("a", 1.0, 0.0),
            cost,
        );
        let busy = SimGpu::new(
            1,
            DeviceConfig::new("b", 1.0, 0.6),
            cost,
        );
        let t_idle = idle.step_time(16);
        let t_busy = busy.step_time(16);
        assert!((t_idle - 0.026).abs() < 1e-12);
        assert!((t_busy - 0.026 / 0.4).abs() < 1e-12);
    }

    #[test]
    fn fit_recovers_affine_cost() {
        let truth = CostModel { fixed_s: 0.004, per_row_s: 0.0012 };
        let samples: Vec<(usize, f64)> = [4usize, 8, 16, 24, 32]
            .iter()
            .map(|&r| (r, truth.step_time(r, 1.0)))
            .collect();
        let fit = CostModel::fit(&samples);
        assert!((fit.fixed_s - truth.fixed_s).abs() < 1e-9);
        assert!((fit.per_row_s - truth.per_row_s).abs() < 1e-9);
    }

    #[test]
    fn json_roundtrip() {
        let c = CostModel { fixed_s: 0.002, per_row_s: 0.0005 };
        let back = CostModel::from_json(&c.to_json()).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn cluster_preserves_order_and_ids() {
        let devs = vec![
            DeviceConfig::new("x", 1.0, 0.0),
            DeviceConfig::new("y", 0.9, 0.2),
        ];
        let cluster = build_cluster(&devs, CostModel::uncalibrated());
        assert_eq!(cluster[0].id, 0);
        assert_eq!(cluster[1].config.name, "y");
        assert!(cluster[1].effective_speed() < 0.73);
    }
}
