//! Peak Signal-to-Noise Ratio between two latents/images.
//!
//! PSNR = 10 log10(peak² / MSE). The paper computes PSNR on [0,255]
//! images; our latents are roughly N(0,1)-scaled, so we use the
//! *joint dynamic range* of the two inputs as the peak — this keeps
//! the paper's qualitative bands (≈9.5 dB for unrelated images, ≈20+
//! dB for near-identical generations) at comparable magnitudes.

use crate::runtime::tensor::Tensor;

/// PSNR in dB with an explicit peak value.
pub fn psnr_with_peak(a: &Tensor, b: &Tensor, peak: f64) -> f64 {
    let mse = a.mse(b);
    if mse == 0.0 {
        return f64::INFINITY;
    }
    10.0 * ((peak * peak) / mse).log10()
}

/// PSNR with the peak taken from the joint dynamic range.
pub fn psnr(a: &Tensor, b: &Tensor) -> f64 {
    let peak = a
        .data
        .iter()
        .chain(b.data.iter())
        .map(|&x| (x as f64).abs())
        .fold(0.0, f64::max)
        .max(1e-12);
    psnr_with_peak(a, b, 2.0 * peak)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::NormalGen;

    #[test]
    fn identical_is_infinite() {
        let mut g = NormalGen::new(1);
        let a = Tensor::new(vec![4, 4, 1], g.vec_f32(16)).unwrap();
        assert!(psnr(&a, &a).is_infinite());
    }

    #[test]
    fn closer_pairs_score_higher() {
        let mut g = NormalGen::new(2);
        let a = Tensor::new(vec![8, 8, 1], g.vec_f32(64)).unwrap();
        let mut near = a.clone();
        for x in near.data.iter_mut() {
            *x += 0.01;
        }
        let far = Tensor::new(vec![8, 8, 1], g.vec_f32(64)).unwrap();
        assert!(psnr(&a, &near) > psnr(&a, &far));
    }

    #[test]
    fn known_value() {
        let a = Tensor::new(vec![1, 1, 2], vec![0.0, 0.0]).unwrap();
        let b = Tensor::new(vec![1, 1, 2], vec![1.0, 1.0]).unwrap();
        // MSE 1, peak 2 -> 10 log10(4) ≈ 6.0206
        assert!((psnr(&a, &b) - 6.0205999).abs() < 1e-4);
    }
}
