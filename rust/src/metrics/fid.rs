//! FID-proxy: Fréchet distance between feature distributions of two
//! image sets, using the final stage (f3, 64-d) of the fixed random
//! feature net instead of InceptionV3 (DESIGN.md §3).
//!
//! FID(X, Y) = ||μx - μy||² + tr(Σx + Σy - 2(Σx Σy)^{1/2})
//!
//! The matrix square root runs through our own Jacobi eigensolver
//! (`linalg`), exactly as the formula demands — only the feature
//! extractor is substituted.

use crate::error::{Error, Result};
use crate::linalg::{col_means, covariance, trace_sqrt_product, Mat};
use crate::runtime::tensor::Tensor;
use crate::runtime::ExecHandle;

/// Feature statistics of an image set.
#[derive(Debug, Clone)]
pub struct FeatureStats {
    pub mu: Vec<f64>,
    pub sigma: Mat,
    pub n: usize,
}

/// Extract final-stage features for a set of latents.
pub fn feature_matrix(rt: &ExecHandle, images: &[Tensor]) -> Result<Mat> {
    if images.is_empty() {
        return Err(Error::msg("empty image set"));
    }
    let mut rows = Vec::with_capacity(images.len());
    for img in images {
        let (_, _, f3) = rt.features(img)?;
        rows.push(f3.iter().map(|&x| x as f64).collect::<Vec<f64>>());
    }
    Ok(Mat::from_rows(&rows))
}

/// Compute μ/Σ for a set.
pub fn stats(rt: &ExecHandle, images: &[Tensor]) -> Result<FeatureStats> {
    let m = feature_matrix(rt, images)?;
    Ok(FeatureStats { mu: col_means(&m), sigma: covariance(&m), n: m.rows })
}

/// Fréchet distance between two feature statistics.
pub fn frechet(a: &FeatureStats, b: &FeatureStats) -> f64 {
    let mean_term: f64 = a
        .mu
        .iter()
        .zip(&b.mu)
        .map(|(x, y)| (x - y) * (x - y))
        .sum();
    let tr = a.sigma.trace() + b.sigma.trace()
        - 2.0 * trace_sqrt_product(&a.sigma, &b.sigma);
    // FID is non-negative in exact arithmetic; clamp eigensolver noise.
    (mean_term + tr).max(0.0)
}

/// FID-proxy between two image sets.
pub fn fid(rt: &ExecHandle, xs: &[Tensor], ys: &[Tensor]) -> Result<f64> {
    Ok(frechet(&stats(rt, xs)?, &stats(rt, ys)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ExecService;
    use crate::util::rng::NormalGen;
    use std::path::PathBuf;

    fn runtime() -> Option<ExecService> {
        if !cfg!(feature = "xla-backend") {
            eprintln!("skipping: built without xla-backend");
            return None;
        }
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(ExecService::spawn(dir).unwrap())
    }

    fn set(seed: u64, n: usize, shift: f32) -> Vec<Tensor> {
        let mut g = NormalGen::new(seed);
        (0..n)
            .map(|_| {
                let mut t =
                    Tensor::new(vec![32, 32, 4], g.vec_f32(4096)).unwrap();
                for x in t.data.iter_mut() {
                    *x += shift;
                }
                t
            })
            .collect()
    }

    #[test]
    fn same_set_scores_near_zero_and_shift_increases() {
        let Some(svc) = runtime() else { return };
        let rt = svc.handle();
        let xs = set(1, 12, 0.0);
        let same = fid(&rt, &xs, &xs).unwrap();
        assert!(same.abs() < 1e-6, "self-FID {same}");

        let ys = set(2, 12, 0.0); // same distribution, different draw
        let zs = set(3, 12, 1.0); // shifted distribution
        let d_same_dist = fid(&rt, &xs, &ys).unwrap();
        let d_shifted = fid(&rt, &xs, &zs).unwrap();
        assert!(
            d_same_dist < d_shifted,
            "{d_same_dist} vs {d_shifted}"
        );
    }

    #[test]
    fn frechet_is_symmetric() {
        let Some(svc) = runtime() else { return };
        let rt = svc.handle();
        let xs = set(4, 10, 0.0);
        let ys = set(5, 10, 0.3);
        let ab = fid(&rt, &xs, &ys).unwrap();
        let ba = fid(&rt, &ys, &xs).unwrap();
        assert!((ab - ba).abs() < 1e-8);
    }
}
