//! SSIM (structural similarity) between two latents — an extra quality
//! metric beyond the paper's PSNR/LPIPS/FID, useful because it is
//! sensitive to the *local structure* changes that patch-boundary
//! staleness introduces (the artifacts Fig. 7 highlights with red
//! boxes tend to be local).
//!
//! Windowed SSIM with an 8x8 uniform window per channel, averaged over
//! windows and channels. The dynamic range L is taken from the joint
//! data range (latents are not [0,255] images).

use crate::runtime::tensor::Tensor;

const WIN: usize = 8;

/// Mean SSIM over all 8x8 windows and channels. Inputs must share
/// shape [H, W, C] with H, W multiples of 8.
pub fn ssim(a: &Tensor, b: &Tensor) -> f64 {
    assert_eq!(a.shape, b.shape);
    assert_eq!(a.shape.len(), 3);
    let (h, w, c) = (a.shape[0], a.shape[1], a.shape[2]);
    assert!(h % WIN == 0 && w % WIN == 0, "H,W must be multiples of 8");

    let lo = a
        .data
        .iter()
        .chain(b.data.iter())
        .cloned()
        .fold(f32::INFINITY, f32::min) as f64;
    let hi = a
        .data
        .iter()
        .chain(b.data.iter())
        .cloned()
        .fold(f32::NEG_INFINITY, f32::max) as f64;
    let l = (hi - lo).max(1e-12);
    let c1 = (0.01 * l) * (0.01 * l);
    let c2 = (0.03 * l) * (0.03 * l);

    let at = |t: &Tensor, y: usize, x: usize, ch: usize| -> f64 {
        t.data[(y * w + x) * c + ch] as f64
    };

    let mut total = 0.0;
    let mut windows = 0usize;
    for ch in 0..c {
        for wy in (0..h).step_by(WIN) {
            for wx in (0..w).step_by(WIN) {
                let n = (WIN * WIN) as f64;
                let (mut ma, mut mb) = (0.0, 0.0);
                for y in wy..wy + WIN {
                    for x in wx..wx + WIN {
                        ma += at(a, y, x, ch);
                        mb += at(b, y, x, ch);
                    }
                }
                ma /= n;
                mb /= n;
                let (mut va, mut vb, mut cov) = (0.0, 0.0, 0.0);
                for y in wy..wy + WIN {
                    for x in wx..wx + WIN {
                        let da = at(a, y, x, ch) - ma;
                        let db = at(b, y, x, ch) - mb;
                        va += da * da;
                        vb += db * db;
                        cov += da * db;
                    }
                }
                va /= n - 1.0;
                vb /= n - 1.0;
                cov /= n - 1.0;
                let s = ((2.0 * ma * mb + c1) * (2.0 * cov + c2))
                    / ((ma * ma + mb * mb + c1) * (va + vb + c2));
                total += s;
                windows += 1;
            }
        }
    }
    total / windows as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::NormalGen;

    #[test]
    fn identical_scores_one() {
        let mut g = NormalGen::new(1);
        let a = Tensor::new(vec![32, 32, 4], g.vec_f32(4096)).unwrap();
        assert!((ssim(&a, &a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn independent_scores_near_zero() {
        let mut g = NormalGen::new(2);
        let a = Tensor::new(vec![32, 32, 4], g.vec_f32(4096)).unwrap();
        let b = Tensor::new(vec![32, 32, 4], g.vec_f32(4096)).unwrap();
        let s = ssim(&a, &b);
        assert!(s.abs() < 0.25, "ssim {s}");
    }

    #[test]
    fn ordering_by_perturbation() {
        let mut g = NormalGen::new(3);
        let a = Tensor::new(vec![32, 32, 4], g.vec_f32(4096)).unwrap();
        let mut near = a.clone();
        let mut far = a.clone();
        let mut gn = NormalGen::new(4);
        for (x, y) in near.data.iter_mut().zip(far.data.iter_mut()) {
            let e = gn.next() as f32;
            *x += 0.05 * e;
            *y += 0.8 * e;
        }
        let s_near = ssim(&a, &near);
        let s_far = ssim(&a, &far);
        assert!(s_near > s_far, "{s_near} vs {s_far}");
        assert!(s_near > 0.9);
    }

    #[test]
    fn symmetric() {
        let mut g = NormalGen::new(5);
        let a = Tensor::new(vec![32, 32, 4], g.vec_f32(4096)).unwrap();
        let mut b = a.clone();
        for x in b.data.iter_mut() {
            *x *= 1.1;
        }
        assert!((ssim(&a, &b) - ssim(&b, &a)).abs() < 1e-12);
    }
}
