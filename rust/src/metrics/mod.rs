//! Quality + performance metrics for the evaluation (paper §V).
//!
//! PSNR is exact; LPIPS and FID are *proxies* built on the fixed
//! random feature net AOT'd in `features.hlo.txt` (DESIGN.md §3
//! documents why the substitution preserves Table II's relative
//! comparisons). They are reported as "LPIPS-proxy"/"FID-proxy"
//! throughout EXPERIMENTS.md.

pub mod fid;
pub mod latency;
pub mod lpips;
pub mod psnr;
pub mod ssim;
