//! Latency / throughput accounting for the serving layer and benches.

use std::time::Instant;

use crate::util::stats;

/// Running latency statistics (per request class).
#[derive(Debug, Default, Clone)]
pub struct LatencyTracker {
    samples_s: Vec<f64>,
}

impl LatencyTracker {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, seconds: f64) {
        self.samples_s.push(seconds);
    }

    pub fn count(&self) -> usize {
        self.samples_s.len()
    }

    pub fn mean(&self) -> f64 {
        stats::mean(&self.samples_s)
    }

    pub fn p50(&self) -> f64 {
        stats::percentile(&self.samples_s, 50.0)
    }

    pub fn p95(&self) -> f64 {
        stats::percentile(&self.samples_s, 95.0)
    }

    pub fn p99(&self) -> f64 {
        stats::percentile(&self.samples_s, 99.0)
    }

    pub fn max(&self) -> f64 {
        stats::max(&self.samples_s)
    }

    /// Requests per second over a window of `wall_s`.
    pub fn throughput(&self, wall_s: f64) -> f64 {
        if wall_s <= 0.0 {
            return 0.0;
        }
        self.count() as f64 / wall_s
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.3}s p50={:.3}s p95={:.3}s max={:.3}s",
            self.count(),
            self.mean(),
            self.p50(),
            self.p95(),
            self.max()
        )
    }
}

/// RAII timer feeding a tracker.
pub struct Timed<'a> {
    tracker: &'a mut LatencyTracker,
    start: Instant,
}

impl<'a> Timed<'a> {
    pub fn new(tracker: &'a mut LatencyTracker) -> Self {
        Timed { tracker, start: Instant::now() }
    }
}

impl Drop for Timed<'_> {
    fn drop(&mut self) {
        self.tracker.record(self.start.elapsed().as_secs_f64());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_percentiles() {
        let mut t = LatencyTracker::new();
        for i in 1..=100 {
            t.record(i as f64 / 100.0);
        }
        assert_eq!(t.count(), 100);
        assert!((t.p50() - 0.505).abs() < 0.01);
        assert!((t.p95() - 0.955).abs() < 0.01);
        assert_eq!(t.max(), 1.0);
        assert!((t.throughput(10.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn timed_records_on_drop() {
        let mut t = LatencyTracker::new();
        {
            let _timer = Timed::new(&mut t);
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(t.count(), 1);
        assert!(t.mean() >= 0.002);
    }
}
