//! Latency / throughput accounting for the serving layer and benches.

use std::time::Instant;

use crate::util::rng::Pcg32;
use crate::util::stats;

/// Default reservoir size: plenty for stable p95/p99 estimates, small
/// enough that a server running for days holds O(1) memory per class.
const DEFAULT_RESERVOIR: usize = 4096;

/// Running latency statistics (per request class).
///
/// Count / mean / max are exact over every recorded sample; the
/// percentiles come from a bounded uniform reservoir (Vitter's
/// Algorithm R over a deterministic PCG stream), so memory stays flat
/// no matter how long the server runs.
#[derive(Debug, Clone)]
pub struct LatencyTracker {
    reservoir: Vec<f64>,
    capacity: usize,
    /// Total samples ever recorded (not just those retained).
    seen: u64,
    sum_s: f64,
    max_s: f64,
    rng: Pcg32,
}

impl Default for LatencyTracker {
    fn default() -> Self {
        Self::with_capacity(DEFAULT_RESERVOIR)
    }
}

impl LatencyTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Tracker with an explicit reservoir bound (>= 1).
    pub fn with_capacity(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        LatencyTracker {
            reservoir: Vec::with_capacity(capacity.min(1024)),
            capacity,
            seen: 0,
            sum_s: 0.0,
            max_s: f64::NEG_INFINITY,
            rng: Pcg32::new(0x1a7e9c),
        }
    }

    pub fn record(&mut self, seconds: f64) {
        self.seen += 1;
        self.sum_s += seconds;
        self.max_s = self.max_s.max(seconds);
        if self.reservoir.len() < self.capacity {
            self.reservoir.push(seconds);
        } else {
            // Algorithm R: keep each of the `seen` samples with equal
            // probability capacity/seen.
            let j = (self.rng.next_u64() % self.seen) as usize;
            if j < self.capacity {
                self.reservoir[j] = seconds;
            }
        }
    }

    pub fn count(&self) -> usize {
        self.seen as usize
    }

    pub fn mean(&self) -> f64 {
        if self.seen == 0 {
            0.0
        } else {
            self.sum_s / self.seen as f64
        }
    }

    pub fn p50(&self) -> f64 {
        stats::percentile(&self.reservoir, 50.0)
    }

    pub fn p95(&self) -> f64 {
        stats::percentile(&self.reservoir, 95.0)
    }

    pub fn p99(&self) -> f64 {
        stats::percentile(&self.reservoir, 99.0)
    }

    pub fn max(&self) -> f64 {
        if self.seen == 0 {
            f64::NEG_INFINITY
        } else {
            self.max_s
        }
    }

    /// Requests per second over a window of `wall_s`.
    pub fn throughput(&self, wall_s: f64) -> f64 {
        if wall_s <= 0.0 {
            return 0.0;
        }
        self.count() as f64 / wall_s
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.3}s p50={:.3}s p95={:.3}s max={:.3}s",
            self.count(),
            self.mean(),
            self.p50(),
            self.p95(),
            self.max()
        )
    }
}

/// RAII timer feeding a tracker.
pub struct Timed<'a> {
    tracker: &'a mut LatencyTracker,
    start: Instant,
}

impl<'a> Timed<'a> {
    pub fn new(tracker: &'a mut LatencyTracker) -> Self {
        Timed { tracker, start: Instant::now() }
    }
}

impl Drop for Timed<'_> {
    fn drop(&mut self) {
        self.tracker.record(self.start.elapsed().as_secs_f64());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_percentiles() {
        let mut t = LatencyTracker::new();
        for i in 1..=100 {
            t.record(i as f64 / 100.0);
        }
        assert_eq!(t.count(), 100);
        assert!((t.p50() - 0.505).abs() < 0.01);
        assert!((t.p95() - 0.955).abs() < 0.01);
        assert_eq!(t.max(), 1.0);
        assert!((t.throughput(10.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn reservoir_stays_bounded_with_faithful_stats() {
        let mut t = LatencyTracker::with_capacity(64);
        for i in 0..10_000 {
            // Uniform ramp 0..1s.
            t.record((i % 1000) as f64 / 1000.0);
        }
        // Exact aggregates survive eviction...
        assert_eq!(t.count(), 10_000);
        assert!((t.mean() - 0.4995).abs() < 1e-9);
        assert!((t.max() - 0.999).abs() < 1e-12);
        // ...while memory stays at the reservoir bound and the
        // percentile estimates stay in the right neighborhood.
        assert!(t.reservoir.len() == 64);
        assert!((t.p50() - 0.5).abs() < 0.15, "p50 {}", t.p50());
        assert!(t.p95() > 0.7, "p95 {}", t.p95());
    }

    #[test]
    fn timed_records_on_drop() {
        let mut t = LatencyTracker::new();
        {
            let _timer = Timed::new(&mut t);
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(t.count(), 1);
        assert!(t.mean() >= 0.002);
    }
}
