//! LPIPS-proxy: perceptual distance under the fixed random conv net
//! (`features.hlo.txt`). Per stage, features are L2-normalized and the
//! squared distance is averaged across stages — LPIPS' structure with
//! a random (not learned) backbone; see DESIGN.md §3.

use crate::error::Result;
use crate::runtime::tensor::Tensor;
use crate::runtime::ExecHandle;

fn normalized(v: &[f32]) -> Vec<f64> {
    let norm = v
        .iter()
        .map(|&x| (x as f64) * (x as f64))
        .sum::<f64>()
        .sqrt()
        .max(1e-12);
    v.iter().map(|&x| x as f64 / norm).collect()
}

fn stage_dist(a: &[f32], b: &[f32]) -> f64 {
    let na = normalized(a);
    let nb = normalized(b);
    na.iter()
        .zip(&nb)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
}

/// LPIPS-proxy distance between two latents (lower = more similar).
pub fn lpips(rt: &ExecHandle, a: &Tensor, b: &Tensor) -> Result<f64> {
    let fa = rt.features(a)?;
    let fb = rt.features(b)?;
    let d = stage_dist(&fa.0, &fb.0)
        + stage_dist(&fa.1, &fb.1)
        + stage_dist(&fa.2, &fb.2);
    Ok(d / 3.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ExecService;
    use crate::util::rng::NormalGen;
    use std::path::PathBuf;

    fn runtime() -> Option<ExecService> {
        if !cfg!(feature = "xla-backend") {
            eprintln!("skipping: built without xla-backend");
            return None;
        }
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(ExecService::spawn(dir).unwrap())
    }

    #[test]
    fn zero_for_identical_and_orders_perturbations() {
        let Some(svc) = runtime() else { return };
        let rt = svc.handle();
        let mut g = NormalGen::new(4);
        let a = Tensor::new(vec![32, 32, 4], g.vec_f32(4096)).unwrap();
        assert!(lpips(&rt, &a, &a).unwrap() < 1e-12);

        let mut small = a.clone();
        for x in small.data.iter_mut() {
            *x += 0.01;
        }
        let mut big = a.clone();
        for x in big.data.iter_mut() {
            *x += 0.5;
        }
        let d_small = lpips(&rt, &a, &small).unwrap();
        let d_big = lpips(&rt, &a, &big).unwrap();
        assert!(d_small < d_big, "{d_small} vs {d_big}");
    }
}
