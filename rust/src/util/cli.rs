//! Declarative CLI argument parser (substrate; no `clap` offline).
//!
//! Supports subcommands, `--flag value`, `--flag=value`, boolean
//! switches, defaults, required flags, and auto-generated `--help`.
//!
//! ```no_run
//! use stadi::util::cli::{Command, Parsed};
//! let cmd = Command::new("generate", "run one diffusion request")
//!     .flag("steps", "M_base step count", Some("100"))
//!     .switch("sim", "use the discrete-event clock");
//! let parsed = cmd.parse(std::env::args().skip(2)).unwrap();
//! let steps: usize = parsed.get_parsed("steps").unwrap();
//! ```

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// One flag specification.
#[derive(Debug, Clone)]
struct FlagSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_switch: bool,
    required: bool,
}

/// A (sub)command with its flag table.
#[derive(Debug, Clone)]
pub struct Command {
    pub name: String,
    pub about: String,
    flags: Vec<FlagSpec>,
}

/// Parse result: flag name -> raw string value.
#[derive(Debug, Clone)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    /// Leftover positional arguments.
    pub positional: Vec<String>,
}

impl Command {
    pub fn new(name: impl Into<String>, about: impl Into<String>) -> Self {
        Command { name: name.into(), about: about.into(), flags: Vec::new() }
    }

    /// A value flag with an optional default (None => optional flag
    /// with no default; use `require` for mandatory ones).
    pub fn flag(
        mut self,
        name: &str,
        help: &str,
        default: Option<&str>,
    ) -> Self {
        self.flags.push(FlagSpec {
            name: name.into(),
            help: help.into(),
            default: default.map(String::from),
            is_switch: false,
            required: false,
        });
        self
    }

    /// A mandatory value flag.
    pub fn require(mut self, name: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.into(),
            help: help.into(),
            default: None,
            is_switch: false,
            required: true,
        });
        self
    }

    /// A boolean switch (present => "true").
    pub fn switch(mut self, name: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.into(),
            help: help.into(),
            default: Some("false".into()),
            is_switch: true,
            required: false,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nflags:\n", self.name, self.about);
        for f in &self.flags {
            let kind = if f.is_switch {
                String::new()
            } else if let Some(d) = &f.default {
                format!(" <value> (default {d})")
            } else if f.required {
                " <value> (required)".into()
            } else {
                " <value>".into()
            };
            s.push_str(&format!("  --{}{}\n      {}\n", f.name, kind, f.help));
        }
        s.push_str("  --help\n      show this message\n");
        s
    }

    fn spec(&self, name: &str) -> Option<&FlagSpec> {
        self.flags.iter().find(|f| f.name == name)
    }

    /// Parse an argument iterator (excluding program + subcommand).
    pub fn parse<I: IntoIterator<Item = String>>(&self, args: I) -> Result<Parsed> {
        let mut values = BTreeMap::new();
        for f in &self.flags {
            if let Some(d) = &f.default {
                values.insert(f.name.clone(), d.clone());
            }
        }
        let mut positional = Vec::new();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                return Err(Error::msg(self.usage()));
            }
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self.spec(&name).ok_or_else(|| {
                    Error::Config(format!(
                        "unknown flag --{name}\n\n{}",
                        self.usage()
                    ))
                })?;
                let value = if spec.is_switch {
                    inline.unwrap_or_else(|| "true".into())
                } else if let Some(v) = inline {
                    v
                } else {
                    it.next().ok_or_else(|| {
                        Error::Config(format!("--{name} needs a value"))
                    })?
                };
                values.insert(name, value);
            } else {
                positional.push(arg);
            }
        }
        for f in &self.flags {
            if f.required && !values.contains_key(&f.name) {
                return Err(Error::Config(format!(
                    "missing required flag --{}",
                    f.name
                )));
            }
        }
        Ok(Parsed { values, positional })
    }
}

impl Parsed {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    /// Get + parse into any FromStr type with a good error message.
    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<T> {
        let raw = self.get(name).ok_or_else(|| {
            Error::Config(format!("flag --{name} not provided"))
        })?;
        raw.parse::<T>().map_err(|_| {
            Error::Config(format!(
                "flag --{name}: cannot parse {raw:?} as {}",
                std::any::type_name::<T>()
            ))
        })
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }

    /// Parse a comma-separated list, e.g. `--occ 0.0,0.4`.
    pub fn get_list<T: std::str::FromStr>(&self, name: &str) -> Result<Vec<T>> {
        let raw = self.get(name).ok_or_else(|| {
            Error::Config(format!("flag --{name} not provided"))
        })?;
        raw.split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim().parse::<T>().map_err(|_| {
                    Error::Config(format!("--{name}: bad element {s:?}"))
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("test", "testing")
            .flag("steps", "step count", Some("100"))
            .switch("sim", "simulate")
            .require("model", "model path")
            .flag("occ", "occupancies", Some("0.0,0.0"))
    }

    fn parse(args: &[&str]) -> Result<Parsed> {
        cmd().parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn defaults_and_required() {
        let p = parse(&["--model", "m.hlo"]).unwrap();
        assert_eq!(p.get("steps"), Some("100"));
        assert!(!p.get_bool("sim"));
        assert_eq!(p.get("model"), Some("m.hlo"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(parse(&[]).is_err());
    }

    #[test]
    fn switch_and_equals_syntax() {
        let p = parse(&["--model=m", "--sim", "--steps=50"]).unwrap();
        assert!(p.get_bool("sim"));
        assert_eq!(p.get_parsed::<usize>("steps").unwrap(), 50);
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(parse(&["--model", "m", "--bogus", "1"]).is_err());
    }

    #[test]
    fn list_parsing() {
        let p = parse(&["--model", "m", "--occ", "0.35, 0.45"]).unwrap();
        let occ: Vec<f64> = p.get_list("occ").unwrap();
        assert_eq!(occ, vec![0.35, 0.45]);
    }

    #[test]
    fn positional_collected() {
        let p = parse(&["--model", "m", "prompt-one"]).unwrap();
        assert_eq!(p.positional, vec!["prompt-one"]);
    }

    #[test]
    fn help_is_error_with_usage() {
        let err = parse(&["--help"]).unwrap_err().to_string();
        assert!(err.contains("--steps"));
        assert!(err.contains("testing"));
    }
}
