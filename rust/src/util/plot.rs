//! ASCII line/scatter plots for bench output (the "figures" of the
//! reproduction render directly in the terminal and in
//! test_output/bench logs).

/// One named series of (x, y) points.
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub marker: char,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: impl Into<String>, marker: char) -> Self {
        Series { name: name.into(), marker, points: Vec::new() }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }

    pub fn from_points(
        name: impl Into<String>,
        marker: char,
        points: &[(f64, f64)],
    ) -> Self {
        Series { name: name.into(), marker, points: points.to_vec() }
    }
}

/// Render series into a `width` x `height` character grid with axis
/// labels and a legend. Y grows upward; points are clipped to range.
pub fn render(series: &[Series], width: usize, height: usize) -> String {
    let pts: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|s| s.points.iter().cloned())
        .collect();
    if pts.is_empty() {
        return "(no data)\n".into();
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &pts {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if (x1 - x0).abs() < 1e-300 {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < 1e-300 {
        y1 = y0 + 1.0;
    }
    // Pad the y range slightly so extremes don't sit on the frame.
    let ypad = (y1 - y0) * 0.05;
    y0 -= ypad;
    y1 += ypad;

    let mut grid = vec![vec![' '; width]; height];
    for s in series {
        for &(x, y) in &s.points {
            let cx = ((x - x0) / (x1 - x0) * (width - 1) as f64).round();
            let cy = ((y - y0) / (y1 - y0) * (height - 1) as f64).round();
            let (cx, cy) = (cx as usize, cy as usize);
            if cx < width && cy < height {
                grid[height - 1 - cy][cx] = s.marker;
            }
        }
    }

    let mut out = String::new();
    for (i, row) in grid.iter().enumerate() {
        let yv = y1 - (y1 - y0) * i as f64 / (height - 1) as f64;
        out.push_str(&format!("{yv:>9.3} |"));
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("{:>9} +{}\n", "", "-".repeat(width)));
    out.push_str(&format!(
        "{:>10} {:<w$.3}{:>.3}\n",
        "",
        x0,
        x1,
        w = width.saturating_sub(5)
    ));
    out.push_str("           ");
    for s in series {
        out.push_str(&format!("[{}] {}   ", s.marker, s.name));
    }
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_points_in_bounds() {
        let s = Series::from_points(
            "lat",
            '*',
            &[(0.0, 1.0), (1.0, 2.0), (2.0, 4.0)],
        );
        let out = render(&[s], 40, 10);
        assert!(out.contains('*'));
        assert!(out.contains("[*] lat"));
        // 10 grid rows + axis + labels + legend
        assert!(out.lines().count() >= 12);
    }

    #[test]
    fn empty_series_is_harmless() {
        assert_eq!(render(&[], 10, 5), "(no data)\n");
    }

    #[test]
    fn constant_series_does_not_divide_by_zero() {
        let s = Series::from_points("c", 'o', &[(1.0, 5.0), (2.0, 5.0)]);
        let out = render(&[s], 20, 5);
        assert!(out.contains('o'));
    }

    #[test]
    fn two_series_distinct_markers() {
        let a = Series::from_points("a", 'a', &[(0.0, 0.0)]);
        let b = Series::from_points("b", 'b', &[(1.0, 1.0)]);
        let out = render(&[a, b], 30, 8);
        assert!(out.contains('a') && out.contains('b'));
    }
}
