//! Support substrates built from scratch (the offline registry carries
//! no serde/clap/rand/criterion/proptest — see DESIGN.md §2).

pub mod benchkit;
pub mod cli;
pub mod json;
pub mod logging;
pub mod plot;
pub mod proptest;
pub mod rng;
pub mod stats;
