//! Bench harness substrate (no `criterion` offline).
//!
//! Provides warmup + timed iterations with mean/stddev/percentiles and
//! a fixed-width table printer, so every `cargo bench` target emits the
//! same rows/series the paper's tables and figures report.

use std::time::Instant;

use crate::util::stats;

/// Timing result of one measured case.
#[derive(Debug, Clone)]
pub struct Sample {
    pub label: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub p50_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Sample {
    pub fn from_times(label: impl Into<String>, times: &[f64]) -> Self {
        Sample {
            label: label.into(),
            iters: times.len(),
            mean_s: stats::mean(times),
            std_s: stats::stddev(times),
            p50_s: stats::median(times),
            min_s: stats::min(times),
            max_s: stats::max(times),
        }
    }
}

/// Run `f` `warmup` times untimed, then `iters` times timed.
pub fn bench<F: FnMut()>(
    label: impl Into<String>,
    warmup: usize,
    iters: usize,
    mut f: F,
) -> Sample {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    Sample::from_times(label, &times)
}

/// Time a single invocation returning (result, seconds).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Fixed-width table printer for bench reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn to_string(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {c:<w$} |", w = w));
            }
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&"-".repeat(w + 2));
            out.push('|');
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_string());
    }
}

/// Format seconds with adaptive units.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}µs", s * 1e6)
    }
}

/// Section banner for bench output.
pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut n = 0;
        let s = bench("x", 2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(s.iters, 5);
        assert!(s.mean_s >= 0.0);
        assert!(s.min_s <= s.p50_s && s.p50_s <= s.max_s);
    }

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["a", "long-header"]);
        t.row(&["xx".into(), "1".into()]);
        let s = t.to_string();
        let lines: Vec<_> = s.lines().collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0].len(), lines[2].len());
    }

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_secs(2.5), "2.500s");
        assert_eq!(fmt_secs(0.0025), "2.500ms");
        assert_eq!(fmt_secs(2.5e-6), "2.5µs");
    }
}
