//! Tiny leveled logger (substrate; no `log`/`tracing` offline).
//!
//! Level picked from `STADI_LOG` (error|warn|info|debug|trace), default
//! `info`. Messages go to stderr so bench stdout stays machine-parsable.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Once;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static INIT: Once = Once::new();
static mut START: Option<Instant> = None;

fn init() {
    INIT.call_once(|| {
        let lvl = match std::env::var("STADI_LOG").as_deref() {
            Ok("error") => Level::Error,
            Ok("warn") => Level::Warn,
            Ok("debug") => Level::Debug,
            Ok("trace") => Level::Trace,
            _ => Level::Info,
        };
        LEVEL.store(lvl as u8, Ordering::Relaxed);
        unsafe {
            START = Some(Instant::now());
        }
    });
}

pub fn set_level(lvl: Level) {
    init();
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn enabled(lvl: Level) -> bool {
    init();
    (lvl as u8) <= LEVEL.load(Ordering::Relaxed)
}

#[doc(hidden)]
pub fn log(lvl: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(lvl) {
        return;
    }
    let elapsed = unsafe {
        #[allow(static_mut_refs)]
        START.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0)
    };
    let tag = match lvl {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{elapsed:9.4}s {tag} {target}] {msg}");
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Error, $target,
            format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn, $target,
            format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info, $target,
            format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug, $target,
            format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_trace {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Trace, $target,
            format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
