//! Mini property-testing framework (substrate; no `proptest` offline).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` on `cases` generated
//! inputs; on failure it greedily shrinks via the input's `Shrink`
//! implementation and panics with the minimal counterexample. Used for
//! the coordinator invariants (routing, batching, scheduling state) as
//! the brief requires.
//!
//! Seeding: each call site passes a fixed default seed, and the
//! `QUICKCHECK_SEED` environment variable overrides it globally — CI
//! sets a per-run value so every run explores a different slice of
//! the input space, and a failure's panic message names the exact
//! seed to re-run with (`QUICKCHECK_SEED=<n> cargo test <name>`).

use crate::util::rng::Pcg32;

/// The seed `forall` will actually use: the `QUICKCHECK_SEED` env
/// override when set (empty = unset), else the call site's default.
/// A set-but-unparseable value panics — silently falling back to the
/// default would make "re-run with QUICKCHECK_SEED=<seed>" look like
/// the CI failure was a flake when the seed was merely mistyped.
pub fn effective_seed(default: u64) -> u64 {
    match std::env::var("QUICKCHECK_SEED") {
        Ok(s) if !s.trim().is_empty() => {
            s.trim().parse::<u64>().unwrap_or_else(|_| {
                panic!("QUICKCHECK_SEED={s:?} is not a u64 seed")
            })
        }
        _ => default,
    }
}

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized + Clone + std::fmt::Debug {
    /// Candidate shrinks, roughly ordered most-aggressive first.
    fn shrinks(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for usize {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for u64 {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for f64 {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
        }
        out
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // Remove halves, then single elements, then shrink elements.
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        if self.len() > 1 {
            for i in 0..self.len() {
                let mut v = self.clone();
                v.remove(i);
                out.push(v);
            }
        }
        for i in 0..self.len() {
            for candidate in self[i].shrinks() {
                let mut v = self.clone();
                v[i] = candidate;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrinks(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrinks()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrinks().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Outcome of a property check.
pub type Check = Result<(), String>;

/// Assert-style helper for properties.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Check {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run `prop` on `cases` inputs from `gen`; shrink on failure. The
/// seed is the call site's default unless `QUICKCHECK_SEED` overrides
/// it (see [`effective_seed`]); failures print the seed that
/// reproduces them.
pub fn forall<T, G, P>(seed: u64, cases: usize, mut gen: G, prop: P)
where
    T: Shrink,
    G: FnMut(&mut Pcg32) -> T,
    P: Fn(&T) -> Check,
{
    let seed = effective_seed(seed);
    let mut rng = Pcg32::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            let (min_input, min_msg) = shrink_loop(input, msg, &prop);
            panic!(
                "property failed (case {case}/{cases}, seed {seed} — \
                 rerun with QUICKCHECK_SEED={seed}):\n  \
                 counterexample: {min_input:?}\n  reason: {min_msg}"
            );
        }
    }
}

fn shrink_loop<T: Shrink, P: Fn(&T) -> Check>(
    mut input: T,
    mut msg: String,
    prop: &P,
) -> (T, String) {
    // Greedy descent: keep taking the first failing shrink, bounded.
    'outer: for _ in 0..1000 {
        for candidate in input.shrinks() {
            if let Err(m) = prop(&candidate) {
                input = candidate;
                msg = m;
                continue 'outer;
            }
        }
        break;
    }
    (input, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(
            1,
            50,
            |rng| rng.below(100) as usize,
            |_| {
                // side channel not available inside Fn; count via gen
                Ok(())
            },
        );
        // count generator calls instead
        forall(
            1,
            50,
            |rng| {
                count += 1;
                rng.below(100) as usize
            },
            |_| Ok(()),
        );
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "counterexample: 10")]
    fn shrinks_to_minimal_failing() {
        // Fails for x >= 10; minimal counterexample should be exactly 10.
        forall(
            3,
            200,
            |rng| rng.below(1000) as usize,
            |&x| ensure(x < 10, format!("{x} >= 10")),
        );
    }

    /// No env mutation (tests run concurrently): assert consistency
    /// with whatever the environment actually says. An unparseable
    /// env seed makes `effective_seed` itself panic loudly, which is
    /// the contract.
    #[test]
    fn effective_seed_prefers_env_override() {
        match std::env::var("QUICKCHECK_SEED") {
            Ok(s) if !s.trim().is_empty() => assert_eq!(
                effective_seed(123),
                s.trim().parse::<u64>().expect(
                    "QUICKCHECK_SEED set but not a u64 — fix the env"
                )
            ),
            _ => assert_eq!(effective_seed(123), 123),
        }
    }

    #[test]
    fn vec_shrink_produces_smaller() {
        let v = vec![3usize, 4, 5];
        let shrinks = v.shrinks();
        assert!(shrinks.iter().any(|s| s.len() < 3));
        assert!(shrinks.iter().all(|s| s.len() <= 3));
    }
}
