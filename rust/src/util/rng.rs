//! Deterministic PRNG substrate (no `rand` crate offline).
//!
//! `Pcg32` (PCG-XSH-RR 64/32) for fast uniform streams and `NormalGen`
//! (Box-Muller) for Gaussians. Seeded explicitly everywhere so every
//! experiment in EXPERIMENTS.md is bit-reproducible.

/// PCG-XSH-RR 64/32 — O'Neill's minimal PCG.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub const MULT: u64 = 6364136223846793005;

    /// Seed with the default stream.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Seed with an explicit stream id (distinct streams are
    /// statistically independent).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(Self::MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // 53 random bits / 2^53.
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [0, 1) as f32.
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 / (1u32 << 24) as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire-ish
    /// rejection).
    pub fn below(&mut self, n: u32) -> u32 {
        assert!(n > 0);
        let threshold = n.wrapping_neg() % n;
        loop {
            let r = self.next_u32();
            if r >= threshold {
                return r % n;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u32) as usize]
    }
}

/// Box-Muller standard-normal generator over a Pcg32 stream.
#[derive(Debug, Clone)]
pub struct NormalGen {
    rng: Pcg32,
    spare: Option<f64>,
}

impl NormalGen {
    pub fn new(seed: u64) -> Self {
        NormalGen { rng: Pcg32::new(seed), spare: None }
    }

    pub fn from_rng(rng: Pcg32) -> Self {
        NormalGen { rng, spare: None }
    }

    /// One standard-normal sample.
    pub fn next(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        // Box-Muller on (0,1] uniforms (avoid ln(0)).
        let u1 = 1.0 - self.rng.next_f64();
        let u2 = self.rng.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * th.sin());
        r * th.cos()
    }

    /// Fill a f32 buffer with N(0,1) samples.
    pub fn fill_f32(&mut self, out: &mut [f32]) {
        for x in out {
            *x = self.next() as f32;
        }
    }

    /// Allocate a standard-normal f32 vector.
    pub fn vec_f32(&mut self, n: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        self.fill_f32(&mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pinned_cross_language_vectors() {
        // Must match python/tests/test_pcg.py (compile/pcg.py mirrors
        // this generator for the golden-vector scheme).
        let mut r = Pcg32::new(7);
        assert_eq!(
            [r.next_u32(), r.next_u32(), r.next_u32(), r.next_u32()],
            [3536637593, 1154887489, 2902756104, 1443040102]
        );
        let mut r = Pcg32::new(42);
        assert_eq!(
            [r.next_u32(), r.next_u32(), r.next_u32(), r.next_u32()],
            [1898997482, 1014631766, 4096008554, 633901381]
        );
        let mut g = NormalGen::new(1);
        let want = [
            2.322744198748,
            -0.446543482722,
            0.586928137232,
            0.618352916784,
        ];
        for w in want {
            assert!((g.next() - w).abs() < 1e-9);
        }
    }

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::new(7);
        let mut b = Pcg32::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn distinct_seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Pcg32::new(99);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg32::new(3);
        let mut seen = [false; 7];
        for _ in 0..500 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut g = NormalGen::new(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = g.next();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
