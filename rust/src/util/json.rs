//! Minimal-but-complete JSON implementation (substrate).
//!
//! The offline registry has no `serde`, so manifests, configs, golden
//! vectors and bench reports all go through this hand-rolled parser /
//! printer. It supports the full JSON grammar (objects, arrays,
//! strings with escapes incl. `\uXXXX`, numbers, bools, null), keeps
//! object key order (insertion order, matching python's `json.dump`),
//! and round-trips `parse ∘ to_string` (a property test below).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// Objects keep insertion order in `keys`; `map` gives O(log n)
    /// lookup. (No hashmap: std's RandomState is fine but ordered
    /// iteration makes diffs and tests deterministic.)
    Obj(Object),
}

/// Insertion-ordered JSON object.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Object {
    keys: Vec<String>,
    map: BTreeMap<String, Value>,
}

impl Object {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, k: impl Into<String>, v: Value) {
        let k = k.into();
        if !self.map.contains_key(&k) {
            self.keys.push(k.clone());
        }
        self.map.insert(k, v);
    }

    pub fn get(&self, k: &str) -> Option<&Value> {
        self.map.get(k)
    }

    pub fn contains(&self, k: &str) -> bool {
        self.map.contains_key(k)
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Value)> {
        self.keys.iter().map(move |k| (k, &self.map[k]))
    }
}

impl Value {
    // ----------------------------------------------------- constructors
    pub fn obj() -> Value {
        Value::Obj(Object::new())
    }

    pub fn from_f64_slice(xs: &[f64]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x)).collect())
    }

    pub fn from_f32_slice(xs: &[f32]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect())
    }

    pub fn from_usize_slice(xs: &[usize]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect())
    }

    // ------------------------------------------------------- accessors
    pub fn as_obj(&self) -> Result<&Object> {
        match self {
            Value::Obj(o) => Ok(o),
            _ => Err(Error::msg(format!("expected object, got {self:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => Err(Error::msg(format!("expected array, got {self:?}"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => Err(Error::msg(format!("expected number, got {self:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            return Err(Error::msg(format!("expected unsigned int, got {n}")));
        }
        Ok(n as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 {
            return Err(Error::msg(format!("expected integer, got {n}")));
        }
        Ok(n as i64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => Err(Error::msg(format!("expected string, got {self:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => Err(Error::msg(format!("expected bool, got {self:?}"))),
        }
    }

    /// `obj["k"]` with a descriptive error.
    pub fn get(&self, key: &str) -> Result<&Value> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| Error::msg(format!("missing key {key:?}")))
    }

    /// Optional key lookup.
    pub fn get_opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(o) => o.get(key),
            _ => None,
        }
    }

    pub fn f64s(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    pub fn f32s(&self) -> Result<Vec<f32>> {
        Ok(self.f64s()?.into_iter().map(|x| x as f32).collect())
    }

    pub fn usizes(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
}

// ------------------------------------------------------------------ parse

/// Parse a complete JSON document (rejects trailing garbage).
pub fn parse(text: &str) -> Result<Value> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> Error {
        Error::Json { offset: self.i, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected {:?}", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected {word}")))
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut obj = Object::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(obj));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            obj.insert(key, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(obj));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(arr));
        }
        loop {
            self.ws();
            arr.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let cp = self.hex4()?;
                            // Surrogate pair handling.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.err("bad low surrogate"));
                                }
                                let c = 0x10000
                                    + ((cp - 0xD800) << 10)
                                    + (lo - 0xDC00);
                                char::from_u32(c)
                                    .ok_or_else(|| self.err("bad codepoint"))?
                            } else {
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?
                            };
                            s.push(ch);
                            continue; // hex4 already advanced past digits
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(c) if c < 0x20 => {
                    return Err(self.err("control char in string"))
                }
                Some(_) => {
                    // Copy one UTF-8 scalar (may be multi-byte).
                    let rest = &self.b[self.i..];
                    let step = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..step.min(rest.len())])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.i += step;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.peek().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
            self.i += 1;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err(format!("bad number {s:?}")))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ------------------------------------------------------------------ print

/// Serialize compactly (no whitespace).
pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(&mut s, v, None, 0);
    s
}

/// Serialize with `indent` spaces per level (like `json.dump(indent=)`).
pub fn to_string_pretty(v: &Value) -> String {
    let mut s = String::new();
    write_value(&mut s, v, Some(1), 0);
    s
}

fn write_value(out: &mut String, v: &Value, indent: Option<usize>, depth: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(n) => write_num(out, *n),
        Value::Str(s) => write_string(out, s),
        Value::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline(out, indent, depth + 1);
                write_value(out, x, indent, depth + 1);
            }
            if !a.is_empty() {
                newline(out, indent, depth);
            }
            out.push(']');
        }
        Value::Obj(o) => {
            out.push('{');
            for (i, (k, x)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline(out, indent, depth + 1);
                write_string(out, k);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(out, x, indent, depth + 1);
            }
            if !o.is_empty() {
                newline(out, indent, depth);
            }
            out.push('}');
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_nan() || n.is_infinite() {
        // JSON has no NaN/Inf; emit null like python's allow_nan=False
        // alternatives would. Callers should avoid this.
        out.push_str("null");
    } else if n == n.trunc() && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        // Shortest roundtrip repr rust provides.
        let _ = write!(out, "{n}");
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ------------------------------------------------------------------ scan

/// Single-pass, zero-allocation scanner over a JSON document's bytes.
///
/// The serve wire hot path ([`crate::serve::protocol`]'s `parse_lazy`)
/// pulls the handful of fields the common request line carries out of
/// the raw bytes without building a [`Value`] tree. The scanner is
/// deliberately conservative: every method returns `None` the moment
/// the input looks even slightly unusual (escape sequences, embedded
/// control characters, malformed numbers), and the caller is expected
/// to bail to the full [`parse`] — which also means every *error* a
/// line can produce still comes from the one tree parser, so error
/// text and offsets stay byte-identical across the two paths.
///
/// After any method returns `None` the scanner position is
/// unspecified; callers must abandon the scan, not resume it.
pub struct Scanner<'a> {
    s: &'a str,
    b: &'a [u8],
    i: usize,
}

impl<'a> Scanner<'a> {
    pub fn new(text: &'a str) -> Self {
        Scanner { s: text, b: text.as_bytes(), i: 0 }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    /// Skip JSON whitespace (space, tab, newline, carriage return) —
    /// the same set the tree parser's `ws()` accepts.
    pub fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    /// Consume `c` (after whitespace); false if the next byte differs
    /// (position then rests on that byte).
    pub fn eat(&mut self, c: u8) -> bool {
        self.skip_ws();
        if self.peek() == Some(c) {
            self.i += 1;
            true
        } else {
            false
        }
    }

    /// True when only trailing whitespace remains.
    pub fn at_end(&mut self) -> bool {
        self.skip_ws();
        self.i == self.b.len()
    }

    /// Scan a string literal and borrow its contents verbatim (no
    /// unescaping, no copy). Returns `None` on a missing opening
    /// quote, any escape sequence, an embedded control character, or
    /// an unterminated literal. Multi-byte UTF-8 passes through
    /// untouched — the quote bytes are ASCII, so the slice bounds
    /// always sit on char boundaries.
    pub fn raw_string(&mut self) -> Option<&'a str> {
        if !self.eat(b'"') {
            return None;
        }
        let start = self.i;
        loop {
            match self.peek() {
                Some(b'"') => {
                    let end = self.i;
                    self.i += 1;
                    return Some(&self.s[start..end]);
                }
                Some(b'\\') => return None,
                Some(c) if c < 0x20 => return None,
                Some(_) => self.i += 1,
                None => return None,
            }
        }
    }

    /// Scan a number with the same span rule as the tree parser
    /// (`-digits[.digits][eE[+-]digits]` then `str::parse::<f64>`), so
    /// an accepted literal yields a bit-identical `f64` on both paths.
    /// `None` if the span fails to parse.
    pub fn number(&mut self) -> Option<f64> {
        self.skip_ws();
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        self.s[start..self.i].parse::<f64>().ok()
    }
}

/// Read + parse a JSON file.
pub fn from_file(path: &std::path::Path) -> Result<Value> {
    let text = std::fs::read_to_string(path)?;
    parse(&text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse(" false ").unwrap(), Value::Bool(false));
        assert_eq!(parse("3.5").unwrap(), Value::Num(3.5));
        assert_eq!(parse("-12").unwrap(), Value::Num(-12.0));
        assert_eq!(parse("1e3").unwrap(), Value::Num(1000.0));
        assert_eq!(parse("2.5e-2").unwrap(), Value::Num(0.025));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_escapes() {
        assert_eq!(
            parse(r#""a\nb\t\"c\"""#).unwrap(),
            Value::Str("a\nb\t\"c\"".into())
        );
        assert_eq!(parse(r#""A""#).unwrap(), Value::Str("A".into()));
        // surrogate pair: U+1F600
        assert_eq!(
            parse(r#""😀""#).unwrap(),
            Value::Str("😀".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "d"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "d");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_usize().unwrap(), 2);
        assert!(arr[2].get("b").unwrap() == &Value::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("[1] x").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("truely").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn object_preserves_insertion_order() {
        let v = parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<_> =
            v.as_obj().unwrap().iter().map(|(k, _)| k.clone()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn print_roundtrip_simple() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":true,"d":null}}"#;
        let v = parse(src).unwrap();
        assert_eq!(to_string(&v), src);
    }

    fn random_value(rng: &mut Pcg32, depth: usize) -> Value {
        match if depth > 3 { rng.next_u32() % 4 } else { rng.next_u32() % 6 } {
            0 => Value::Null,
            1 => Value::Bool(rng.next_u32() % 2 == 0),
            2 => Value::Num((rng.next_u32() as f64) / 7.0 - 1000.0),
            3 => {
                let n = rng.next_u32() % 8;
                Value::Str(
                    (0..n)
                        .map(|_| {
                            let c = rng.next_u32() % 128;
                            char::from_u32(c.max(32)).unwrap()
                        })
                        .collect(),
                )
            }
            4 => Value::Arr(
                (0..rng.next_u32() % 4)
                    .map(|_| random_value(rng, depth + 1))
                    .collect(),
            ),
            _ => {
                let mut o = Object::new();
                for i in 0..rng.next_u32() % 4 {
                    o.insert(format!("k{i}"), random_value(rng, depth + 1));
                }
                Value::Obj(o)
            }
        }
    }

    #[test]
    fn property_roundtrip_random_values() {
        // parse(to_string(v)) == v for arbitrary values (numbers chosen
        // exactly representable through the printer).
        let mut rng = Pcg32::new(42);
        for _ in 0..200 {
            let v = random_value(&mut rng, 0);
            let s = to_string(&v);
            let back = parse(&s).unwrap_or_else(|e| {
                panic!("failed to reparse {s:?}: {e}")
            });
            // Numbers go through f64 printing; compare via re-print.
            assert_eq!(to_string(&back), s);
        }
    }

    #[test]
    fn pretty_print_parses_back() {
        let v = parse(r#"{"a": [1, 2], "b": {"c": true}}"#).unwrap();
        let pretty = to_string_pretty(&v);
        assert!(pretty.contains('\n'));
        assert_eq!(parse(&pretty).unwrap(), v);
    }

    #[test]
    fn reads_python_style_floats() {
        // python json.dump writes e.g. 0.00085, 1e-05, large ints.
        let v = parse(r#"[0.00085, 1e-05, 563920, -0.0]"#).unwrap();
        let xs = v.f64s().unwrap();
        assert!((xs[0] - 0.00085).abs() < 1e-12);
        assert!((xs[1] - 1e-5).abs() < 1e-12);
        assert_eq!(xs[2], 563920.0);
    }

    #[test]
    fn scanner_walks_the_common_request_shape() {
        let mut sc = Scanner::new(r#" {"id": "r-1", "seed": 42} "#);
        assert!(sc.eat(b'{'));
        assert_eq!(sc.raw_string(), Some("id"));
        assert!(sc.eat(b':'));
        assert_eq!(sc.raw_string(), Some("r-1"));
        assert!(sc.eat(b','));
        assert_eq!(sc.raw_string(), Some("seed"));
        assert!(sc.eat(b':'));
        assert_eq!(sc.number(), Some(42.0));
        assert!(sc.eat(b'}'));
        assert!(sc.at_end());
    }

    #[test]
    fn scanner_bails_on_anything_unusual() {
        // Escapes, control chars, unterminated strings: all None.
        assert_eq!(Scanner::new(r#""a\nb""#).raw_string(), None);
        assert_eq!(Scanner::new("\"a\tb\"").raw_string(), None);
        assert_eq!(Scanner::new("\"open").raw_string(), None);
        assert_eq!(Scanner::new("42").raw_string(), None);
        // Malformed numbers: None. Wrong token: None.
        assert_eq!(Scanner::new("-").number(), None);
        assert_eq!(Scanner::new("true").number(), None);
        assert_eq!(Scanner::new("\"5\"").number(), None);
        // Multi-byte UTF-8 passes through verbatim.
        assert_eq!(Scanner::new("\"héllo😀\"").raw_string(), Some("héllo😀"));
    }

    #[test]
    fn scanner_numbers_match_tree_parser_bit_for_bit() {
        for lit in
            ["0", "-12", "3.5", "2.5e-2", "1e3", "9007199254740991", "-0.0"]
        {
            let tree = match parse(lit).unwrap() {
                Value::Num(n) => n,
                v => panic!("expected number, got {v:?}"),
            };
            let scanned = Scanner::new(lit).number().unwrap();
            assert_eq!(
                tree.to_bits(),
                scanned.to_bits(),
                "literal {lit:?} diverged"
            );
        }
    }
}
