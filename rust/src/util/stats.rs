//! Small statistics helpers used by the profiler, benches and metrics.

/// Arithmetic mean (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile via linear interpolation on the sorted copy
/// (q in [0, 100]).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        let w = pos - lo as f64;
        s[lo] * (1.0 - w) + s[hi] * w
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Exponentially-weighted moving average (the profiler's estimator for
/// "historical inference time profiles", paper §V).
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Ewma { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Ordinary least squares fit y = a + b*x; returns (a, b, r2).
/// Used by the theory bench to check the O(1/M) drift slope.
pub fn linear_fit(x: &[f64], y: &[f64]) -> (f64, f64, f64) {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    let mx = mean(x);
    let my = mean(y);
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for (&xi, &yi) in x.iter().zip(y) {
        sxx += (xi - mx) * (xi - mx);
        sxy += (xi - mx) * (yi - my);
        syy += (yi - my) * (yi - my);
    }
    if sxx == 0.0 || n < 2.0 {
        return (my, 0.0, 0.0);
    }
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (a, b, r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        e.update(0.0);
        for _ in 0..30 {
            e.update(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-6);
    }

    #[test]
    fn fit_recovers_line() {
        let x: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 + 2.0 * v).collect();
        let (a, b, r2) = linear_fit(&x, &y);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }
}
