//! Communication manager: in-process collectives + the α-β cost model.
//!
//! Substitutes NCCL over PCIe (DESIGN.md §3). Two halves:
//!
//! * `cost` — pure latency/bandwidth estimates consumed by the
//!   timeline simulator (both uneven-all-gather strategies from paper
//!   §V: pad-to-max all_gather vs multi-broadcast emulation);
//! * `CollectiveBus` — real synchronization for threaded mode:
//!   blocking uneven all-gather across participant subsets, plus
//!   non-blocking `publish`/`peek` mailboxes that reproduce
//!   DistriFusion's *asynchronous, staleness-tolerant* buffer update
//!   (a reader never blocks; it sees whatever was last published).

use std::collections::BTreeMap;
use std::sync::{Arc, Condvar, Mutex};

use crate::config::{CommConfig, UnevenStrategy};
use crate::error::{Error, Result};

// ---------------------------------------------------------------- cost

/// Cost of one point-to-point transfer of `bytes`.
pub fn p2p_cost(cfg: &CommConfig, bytes: usize) -> f64 {
    cfg.latency_s + bytes as f64 / cfg.bandwidth_bytes_per_s
}

/// Cost of an uneven all-gather among `sizes.len()` ranks with the
/// given per-rank byte sizes.
///
/// * PadAllGather: every rank contributes max(sizes); ring all-gather
///   costs (n-1) transfers of the padded chunk.
/// * MultiBroadcast: each rank broadcasts its own chunk; total is the
///   sum of per-rank broadcasts (serialized on the PCIe root complex,
///   which is what the paper's multi-broadcast emulation does).
pub fn all_gather_cost(cfg: &CommConfig, sizes: &[usize]) -> f64 {
    let n = sizes.len();
    if n <= 1 {
        return 0.0;
    }
    match cfg.uneven_strategy {
        UnevenStrategy::PadAllGather => {
            let max = *sizes.iter().max().unwrap();
            (n - 1) as f64 * p2p_cost(cfg, max)
        }
        UnevenStrategy::MultiBroadcast => {
            sizes.iter().map(|&s| p2p_cost(cfg, s)).sum()
        }
    }
}

/// Cost of publishing `bytes` to the displaced-halo mailbox: one
/// point-to-point transfer under the same α+β model the timeline
/// charges everywhere else. The displaced path used to be priced ad
/// hoc; pinning `publish_cost == p2p_cost` for equal payloads removes
/// the `CommConfig` cost asymmetry (publish is a single directed
/// transfer — the strategy knob only shapes *collectives*).
pub fn publish_cost(cfg: &CommConfig, bytes: usize) -> f64 {
    p2p_cost(cfg, bytes)
}

/// Cost of one displaced halo exchange among ranks with the given
/// per-rank payload sizes: every rank's publish still crosses the
/// wire (same strategy-shaped total as the blocking gather — the
/// bytes are identical), but the *charging* differs: the timeline
/// overlaps this cost with the next compute span instead of blocking
/// on it. Routed through [`publish_cost`] so the α+β model stays
/// single-sourced.
pub fn displaced_exchange_cost(cfg: &CommConfig, sizes: &[usize]) -> f64 {
    let n = sizes.len();
    if n <= 1 {
        return 0.0;
    }
    match cfg.uneven_strategy {
        UnevenStrategy::PadAllGather => {
            let max = *sizes.iter().max().unwrap();
            (n - 1) as f64 * publish_cost(cfg, max)
        }
        UnevenStrategy::MultiBroadcast => {
            sizes.iter().map(|&s| publish_cost(cfg, s)).sum()
        }
    }
}

/// Cost of a synchronous all-reduce of `bytes` on every rank (ring:
/// 2(n-1)/n · bytes on the wire per rank, (2n-2) latency hops). Used by
/// the tensor-parallelism baseline.
pub fn all_reduce_cost(cfg: &CommConfig, bytes: usize, n: usize) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let hops = 2 * (n - 1);
    hops as f64 * cfg.latency_s
        + 2.0 * (n - 1) as f64 / n as f64 * bytes as f64
            / cfg.bandwidth_bytes_per_s
}

// ------------------------------------------------------------- threaded

/// State of one named blocking collective.
#[derive(Default)]
struct GatherState {
    /// generation -> rank -> payload
    contributions: BTreeMap<u64, BTreeMap<usize, Vec<f32>>>,
    /// per-rank generation counters
    generations: BTreeMap<usize, u64>,
}

/// Mailbox slot for async publish/peek.
#[derive(Default, Clone)]
struct MailSlot {
    data: Option<Arc<Vec<f32>>>,
    version: u64,
}

struct BusInner {
    gathers: Mutex<BTreeMap<String, GatherState>>,
    gather_cv: Condvar,
    mail: Mutex<BTreeMap<(usize, String), MailSlot>>,
    /// Wire-byte counters for accounting (gathered, published).
    bytes_gathered: Mutex<u64>,
    bytes_published: Mutex<u64>,
}

/// In-process collective bus shared by worker threads.
#[derive(Clone)]
pub struct CollectiveBus {
    inner: Arc<BusInner>,
}

impl CollectiveBus {
    pub fn new() -> Self {
        CollectiveBus {
            inner: Arc::new(BusInner {
                gathers: Mutex::new(BTreeMap::new()),
                gather_cv: Condvar::new(),
                mail: Mutex::new(BTreeMap::new()),
                bytes_gathered: Mutex::new(0),
                bytes_published: Mutex::new(0),
            }),
        }
    }

    /// Blocking uneven all-gather on channel `name` among the ranks in
    /// `participants` (must be identical across callers). Returns every
    /// participant's payload keyed by rank. Generation-counted so the
    /// same channel can be reused across steps.
    pub fn all_gather(
        &self,
        name: &str,
        rank: usize,
        participants: &[usize],
        payload: Vec<f32>,
    ) -> Result<BTreeMap<usize, Vec<f32>>> {
        if !participants.contains(&rank) {
            return Err(Error::Comm(format!(
                "rank {rank} not in participants {participants:?}"
            )));
        }
        *self.inner.bytes_gathered.lock().unwrap() +=
            (payload.len() * 4) as u64;
        let mut g = self.inner.gathers.lock().unwrap();
        let state = g.entry(name.to_string()).or_default();
        let gen = {
            let c = state.generations.entry(rank).or_insert(0);
            let gen = *c;
            *c += 1;
            gen
        };
        state
            .contributions
            .entry(gen)
            .or_default()
            .insert(rank, payload);
        self.inner.gather_cv.notify_all();
        loop {
            let ready = g
                .get(name)
                .and_then(|s| s.contributions.get(&gen))
                .map(|m| participants.iter().all(|r| m.contains_key(r)))
                .unwrap_or(false);
            if ready {
                break;
            }
            g = self.inner.gather_cv.wait(g).unwrap();
        }
        let state = g.get_mut(name).unwrap();
        // Last participant to observe readiness cleans up; others clone.
        let m = state.contributions.get(&gen).unwrap().clone();
        // Cleanup once everyone has a chance to read: track reads.
        // Simpler: keep at most 2 generations alive.
        let stale: Vec<u64> = state
            .contributions
            .keys()
            .cloned()
            .filter(|&k| k + 2 <= gen)
            .collect();
        for k in stale {
            state.contributions.remove(&k);
        }
        Ok(m)
    }

    /// Non-blocking publish to (rank, channel) — the async buffer
    /// update of Alg. 1 line 17/23. Overwrites the previous version.
    pub fn publish(&self, rank: usize, channel: &str, data: Vec<f32>) {
        *self.inner.bytes_published.lock().unwrap() +=
            (data.len() * 4) as u64;
        let mut mail = self.inner.mail.lock().unwrap();
        let slot = mail
            .entry((rank, channel.to_string()))
            .or_default();
        slot.version += 1;
        slot.data = Some(Arc::new(data));
    }

    /// Non-blocking read of another rank's latest published buffer
    /// (None until the first publish). Staleness is allowed by design.
    pub fn peek(&self, rank: usize, channel: &str) -> Option<Arc<Vec<f32>>> {
        self.inner
            .mail
            .lock()
            .unwrap()
            .get(&(rank, channel.to_string()))
            .and_then(|s| s.data.clone())
    }

    /// Version counter for staleness diagnostics.
    pub fn peek_version(&self, rank: usize, channel: &str) -> u64 {
        self.inner
            .mail
            .lock()
            .unwrap()
            .get(&(rank, channel.to_string()))
            .map(|s| s.version)
            .unwrap_or(0)
    }

    pub fn bytes_gathered(&self) -> u64 {
        *self.inner.bytes_gathered.lock().unwrap()
    }

    pub fn bytes_published(&self) -> u64 {
        *self.inner.bytes_published.lock().unwrap()
    }
}

impl Default for CollectiveBus {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn cfg(strategy: UnevenStrategy) -> CommConfig {
        CommConfig {
            latency_s: 1e-5,
            bandwidth_bytes_per_s: 1e9,
            uneven_strategy: strategy,
        }
    }

    #[test]
    fn p2p_cost_is_alpha_beta() {
        let c = cfg(UnevenStrategy::PadAllGather);
        let t = p2p_cost(&c, 1_000_000);
        assert!((t - (1e-5 + 1e-3)).abs() < 1e-12);
    }

    #[test]
    fn pad_vs_broadcast_cost_tradeoff() {
        // Even sizes: pad(ring) beats serialized broadcasts for n=2?
        // pad: 1 transfer of max; bcast: 2 transfers (sum). With equal
        // sizes bcast = 2x pad's bytes.
        let sizes = [1000, 1000];
        let pad = all_gather_cost(&cfg(UnevenStrategy::PadAllGather), &sizes);
        let bc = all_gather_cost(&cfg(UnevenStrategy::MultiBroadcast), &sizes);
        assert!(pad < bc);
        // Skewed sizes with several small ranks: each padded round
        // moves the max chunk, so padding wastes and broadcast wins.
        let sizes = [4_000_000, 4, 4, 4];
        let pad = all_gather_cost(&cfg(UnevenStrategy::PadAllGather), &sizes);
        let bc = all_gather_cost(&cfg(UnevenStrategy::MultiBroadcast), &sizes);
        assert!(bc < pad);
    }

    #[test]
    fn publish_cost_matches_p2p_for_equal_payloads() {
        // The cost-asymmetry fix: the displaced publish path prices
        // bytes with the exact α+β model the timeline charges.
        for strategy in
            [UnevenStrategy::PadAllGather, UnevenStrategy::MultiBroadcast]
        {
            let c = cfg(strategy);
            for bytes in [0usize, 1, 4096, 1_000_000] {
                assert_eq!(publish_cost(&c, bytes), p2p_cost(&c, bytes));
            }
        }
    }

    #[test]
    fn displaced_exchange_cost_equals_all_gather_cost() {
        // Same bytes cross the wire either way — only the *charging*
        // (blocking vs overlapped) differs, which is the timeline's
        // job, not the cost model's.
        for strategy in
            [UnevenStrategy::PadAllGather, UnevenStrategy::MultiBroadcast]
        {
            let c = cfg(strategy);
            for sizes in [
                vec![1000usize, 1000],
                vec![4_000_000, 4, 4, 4],
                vec![123],
                vec![],
            ] {
                assert_eq!(
                    displaced_exchange_cost(&c, &sizes),
                    all_gather_cost(&c, &sizes),
                    "{strategy:?} {sizes:?}"
                );
            }
        }
    }

    #[test]
    fn all_reduce_scales_with_ranks() {
        let c = cfg(UnevenStrategy::PadAllGather);
        let t2 = all_reduce_cost(&c, 1_000_000, 2);
        let t4 = all_reduce_cost(&c, 1_000_000, 4);
        assert!(t4 > t2);
        assert_eq!(all_reduce_cost(&c, 123, 1), 0.0);
    }

    #[test]
    fn threaded_all_gather_uneven() {
        let bus = CollectiveBus::new();
        let parts = vec![0usize, 1, 2];
        let mut handles = Vec::new();
        for rank in 0..3usize {
            let bus = bus.clone();
            let parts = parts.clone();
            handles.push(thread::spawn(move || {
                // Uneven payloads: rank r sends r+1 elements of value r.
                let payload = vec![rank as f32; rank + 1];
                bus.all_gather("x", rank, &parts, payload).unwrap()
            }));
        }
        for h in handles {
            let m = h.join().unwrap();
            for r in 0..3usize {
                assert_eq!(m[&r], vec![r as f32; r + 1]);
            }
        }
        assert_eq!(bus.bytes_gathered(), ((1 + 2 + 3) * 4) as u64);
    }

    #[test]
    fn repeated_gathers_use_generations() {
        let bus = CollectiveBus::new();
        let parts = vec![0usize, 1];
        for step in 0..5 {
            let mut handles = Vec::new();
            for rank in 0..2usize {
                let bus = bus.clone();
                let parts = parts.clone();
                handles.push(thread::spawn(move || {
                    bus.all_gather(
                        "x",
                        rank,
                        &parts,
                        vec![(step * 10 + rank) as f32],
                    )
                    .unwrap()
                }));
            }
            for h in handles {
                let m = h.join().unwrap();
                assert_eq!(m[&0], vec![(step * 10) as f32]);
                assert_eq!(m[&1], vec![(step * 10 + 1) as f32]);
            }
        }
    }

    #[test]
    fn publish_peek_is_nonblocking_and_stale_tolerant() {
        let bus = CollectiveBus::new();
        assert!(bus.peek(0, "kv").is_none());
        bus.publish(0, "kv", vec![1.0, 2.0]);
        assert_eq!(*bus.peek(0, "kv").unwrap(), vec![1.0, 2.0]);
        // Reader keeps seeing the old version until a new publish —
        // staleness by design.
        assert_eq!(bus.peek_version(0, "kv"), 1);
        bus.publish(0, "kv", vec![3.0]);
        assert_eq!(*bus.peek(0, "kv").unwrap(), vec![3.0]);
        assert_eq!(bus.peek_version(0, "kv"), 2);
        assert_eq!(bus.bytes_published(), 12);
    }

    #[test]
    fn gather_rejects_non_participant() {
        let bus = CollectiveBus::new();
        assert!(bus.all_gather("x", 5, &[0, 1], vec![]).is_err());
    }
}
