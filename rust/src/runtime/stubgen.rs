//! Synthetic "stub" artifact sets — the offline execution story.
//!
//! `write_stub_artifacts` emits a complete artifact directory
//! (manifest.json + params.bin + placeholder HLO files) for a tiny
//! model, including a `resolutions` table of extra latent sizes, all
//! without touching python or a registry. The manifest carries
//! `"stub": true`, which routes [`crate::runtime::ExecService`] to the
//! deterministic stub backend ([`crate::runtime::stub_exec::StubExec`])
//! instead of PJRT — so the entire engine (planner, sessions, serve
//! stack, fleet, multi-resolution registry) runs end-to-end on a bare
//! toolchain with pinned numerics. Real manifests never set the flag
//! and are unaffected.
//!
//! The CLI front door is `stadi stub-artifacts --out DIR`.

use std::path::Path;

use crate::error::{Error, Result};
use crate::util::json::{self, Object, Value};
use crate::util::rng::NormalGen;

/// Geometry of the stub model (small enough that a full request is a
/// few milliseconds of arithmetic).
pub const LATENT_H: usize = 32;
pub const LATENT_W: usize = 32;
pub const LATENT_C: usize = 4;
pub const PATCH: usize = 2;
pub const DIM: usize = 16;
pub const HEADS: usize = 2;
pub const LAYERS: usize = 2;
pub const TEMB_DIM: usize = 8;
pub const ROW_GRANULARITY: usize = 4;
pub const PARAM_COUNT: usize = 64;
pub const PARAMS_SEED: u64 = 7;

/// The two extra synthetic resolutions the default stub set compiles:
/// a half-height interactive size and a 1.5x-height "high-res" size
/// (latent rows x cols; x8 for pixels).
pub const DEFAULT_EXTRA_RESOLUTIONS: &[(usize, usize)] = &[(16, 32), (48, 32)];

fn tokens_full(h: usize, w: usize) -> usize {
    (h / PATCH) * (w / PATCH)
}

fn slot(name: &str, shape: &[usize]) -> Value {
    let mut o = Object::new();
    o.insert("name", Value::Str(name.into()));
    o.insert("shape", Value::from_usize_slice(shape));
    o.insert("dtype", Value::Str("f32".into()));
    Value::Obj(o)
}

fn num(n: usize) -> Value {
    Value::Num(n as f64)
}

/// One denoiser artifact entry (and its placeholder file on disk).
fn denoiser_entry(
    dir: &Path,
    key: &str,
    res_h: usize,
    res_w: usize,
    patch_h: usize,
    with_patch_h: bool,
) -> Result<Value> {
    let file = format!("{key}.hlo");
    let content = format!(
        "stub-hlo {key} (synthetic placeholder for the {res_h}x{res_w} \
         latent; executed by the deterministic stub backend, not PJRT)\n"
    );
    std::fs::write(dir.join(&file), &content)?;
    let toks = tokens_full(res_h, res_w);
    let own = tokens_full(patch_h, res_w);
    let mut o = Object::new();
    o.insert("file", Value::Str(file));
    o.insert("bytes", num(content.len()));
    if with_patch_h {
        o.insert("patch_h", num(patch_h));
    }
    o.insert(
        "inputs",
        Value::Arr(vec![
            slot("params", &[PARAM_COUNT]),
            slot("x_patch", &[patch_h, res_w, LATENT_C]),
            slot("kv_stale", &[LAYERS, toks, 2 * DIM]),
            slot("row_off", &[]),
            slot("t", &[]),
            slot("cond", &[DIM]),
        ]),
    );
    o.insert(
        "outputs",
        Value::Arr(vec![
            slot("eps_patch", &[patch_h, res_w, LATENT_C]),
            slot("kv_fresh", &[LAYERS, own, 2 * DIM]),
        ]),
    );
    Ok(Value::Obj(o))
}

/// Write a complete synthetic artifact set to `dir`: the native
/// 32x32-latent model plus one registry entry per `(latent_h,
/// latent_w)` in `extra`. Each extra resolution gets denoiser
/// artifacts for every granularity-aligned patch height, exactly like
/// a real AOT run would.
pub fn write_stub_artifacts(
    dir: impl AsRef<Path>,
    extra: &[(usize, usize)],
) -> Result<()> {
    write_stub_artifacts_with_drift(dir, extra, None)
}

/// [`write_stub_artifacts`] plus an optional deterministic occupancy
/// drift schedule embedded in the manifest (`"drift"` table) — the
/// drift-injection harness: any engine opened over the set replays
/// the schedule on its virtual clocks, so integration tests can force
/// a known drift at a known step on any build. CLI:
/// `stadi stub-artifacts --drift "0@0;0@0,0.6@4"`.
pub fn write_stub_artifacts_with_drift(
    dir: impl AsRef<Path>,
    extra: &[(usize, usize)],
    drift: Option<&crate::device::OccupancySchedule>,
) -> Result<()> {
    write_stub_artifacts_full(dir, extra, drift, None)
}

/// [`write_stub_artifacts_with_drift`] plus an optional `kv_gain`
/// manifest key: the stub backend mixes this fraction of the stale KV
/// context into each eps sample, coupling a device's output to its
/// *neighbors'* published halos. Without it the stub's arithmetic is
/// purely local, so displaced-halo staleness would be invisible —
/// with it, the halo quality gate measures a real (bounded,
/// deterministic) PSNR/SSIM drift per staleness budget. CLI:
/// `stadi stub-artifacts --kv-gain 0.05`. Absent (or 0) keeps the
/// exact legacy arithmetic byte for byte.
pub fn write_stub_artifacts_full(
    dir: impl AsRef<Path>,
    extra: &[(usize, usize)],
    drift: Option<&crate::device::OccupancySchedule>,
    kv_gain: Option<f64>,
) -> Result<()> {
    let dir = dir.as_ref();
    if let Some(g) = kv_gain {
        if !(0.0..=1.0).contains(&g) {
            return Err(Error::Artifact(format!(
                "kv_gain {g} outside [0, 1]"
            )));
        }
    }
    std::fs::create_dir_all(dir)?;

    // Deterministic weights (the stub backend mixes them into its
    // stream seeds only through params_seed, but length is validated
    // exactly like the real path).
    let params = NormalGen::new(PARAMS_SEED).vec_f32(PARAM_COUNT);
    let mut bytes = Vec::with_capacity(PARAM_COUNT * 4);
    for p in &params {
        bytes.extend_from_slice(&p.to_le_bytes());
    }
    std::fs::write(dir.join("params.bin"), &bytes)?;

    let mut model = Object::new();
    model.insert("latent_h", num(LATENT_H));
    model.insert("latent_w", num(LATENT_W));
    model.insert("latent_c", num(LATENT_C));
    model.insert("patch", num(PATCH));
    model.insert("dim", num(DIM));
    model.insert("heads", num(HEADS));
    model.insert("layers", num(LAYERS));
    model.insert("temb_dim", num(TEMB_DIM));
    model.insert("row_granularity", num(ROW_GRANULARITY));
    model.insert("tokens_full", num(tokens_full(LATENT_H, LATENT_W)));
    model.insert("param_count", num(PARAM_COUNT));
    model.insert("params_seed", num(PARAMS_SEED as usize));

    let mut schedule = Object::new();
    schedule.insert("train_steps", num(1000));
    schedule.insert("beta_start", Value::Num(0.00085));
    schedule.insert("beta_end", Value::Num(0.012));

    // Native denoisers use the legacy key shape (`denoiser_h{h}`) so
    // the base manifest parses through the unchanged legacy path.
    let mut artifacts = Object::new();
    let mut h = ROW_GRANULARITY;
    while h <= LATENT_H {
        let key = format!("denoiser_h{h}");
        artifacts.insert(
            key.clone(),
            denoiser_entry(dir, &key, LATENT_H, LATENT_W, h, false)?,
        );
        h += ROW_GRANULARITY;
    }

    let mut resolutions = Object::new();
    for &(rh, rw) in extra {
        if rh == 0
            || rw == 0
            || rh % ROW_GRANULARITY != 0
            || rw % PATCH != 0
        {
            return Err(Error::Artifact(format!(
                "stub resolution {rh}x{rw} must be positive, \
                 row-granularity-aligned ({ROW_GRANULARITY}) and \
                 patch-aligned ({PATCH})"
            )));
        }
        // Catch at write time what the registry would reject at load
        // time — a set that can never load helps nobody.
        if (rh, rw) == (LATENT_H, LATENT_W) {
            return Err(Error::Artifact(format!(
                "stub resolution {rh}x{rw} duplicates the native \
                 resolution (it is always registered)"
            )));
        }
        if resolutions.contains(&format!("{rh}x{rw}")) {
            return Err(Error::Artifact(format!(
                "duplicate stub resolution {rh}x{rw}"
            )));
        }
        let mut entry = Object::new();
        entry.insert("latent_h", num(rh));
        entry.insert("latent_w", num(rw));
        entry.insert("tokens_full", num(tokens_full(rh, rw)));
        entry.insert(
            "kv_shape",
            Value::from_usize_slice(&[
                LAYERS,
                tokens_full(rh, rw),
                2 * DIM,
            ]),
        );
        let mut arts = Object::new();
        let mut ph = ROW_GRANULARITY;
        while ph <= rh {
            let key = format!("denoiser_{rh}x{rw}_h{ph}");
            arts.insert(
                key.clone(),
                denoiser_entry(dir, &key, rh, rw, ph, true)?,
            );
            ph += ROW_GRANULARITY;
        }
        entry.insert("artifacts", Value::Obj(arts));
        resolutions.insert(format!("{rh}x{rw}"), Value::Obj(entry));
    }

    let mut manifest = Object::new();
    manifest.insert("stub", Value::Bool(true));
    manifest.insert("model", Value::Obj(model));
    manifest.insert("schedule", Value::Obj(schedule));
    manifest.insert("artifacts", Value::Obj(artifacts));
    if !resolutions.is_empty() {
        manifest.insert("resolutions", Value::Obj(resolutions));
    }
    if let Some(d) = drift {
        manifest.insert("drift", d.to_json());
    }
    if let Some(g) = kv_gain {
        manifest.insert("kv_gain", Value::Num(g));
    }
    std::fs::write(
        dir.join("manifest.json"),
        json::to_string_pretty(&Value::Obj(manifest)),
    )?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::{ArtifactRegistry, Manifest, ResKey};

    fn tmp(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("stadi-stubgen-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn generated_set_loads_as_manifest_and_registry() {
        let dir = tmp("load");
        write_stub_artifacts(&dir, DEFAULT_EXTRA_RESOLUTIONS).unwrap();
        // The base manifest parses through the unchanged legacy path.
        let m = Manifest::load(&dir).unwrap();
        assert!(m.stub);
        assert_eq!(m.model.latent_h, LATENT_H);
        assert_eq!(m.model.tokens_full, 256);
        assert_eq!(m.patch_heights, vec![4, 8, 12, 16, 20, 24, 28, 32]);
        let params = m.load_params().unwrap();
        assert_eq!(params.len(), PARAM_COUNT);
        // The registry sees native + the two synthetic resolutions.
        let reg = ArtifactRegistry::load(&dir).unwrap();
        assert_eq!(reg.native_key(), ResKey { h: 32, w: 32 });
        assert_eq!(
            reg.registered(),
            vec![
                ResKey { h: 32, w: 32 },
                ResKey { h: 16, w: 32 },
                ResKey { h: 48, w: 32 },
            ]
        );
        let ra = reg.get(ResKey { h: 16, w: 32 }).unwrap();
        assert_eq!(ra.model.latent_h, 16);
        assert_eq!(ra.model.tokens_full, 128);
        assert_eq!(ra.patch_heights, vec![4, 8, 12, 16]);
        ra.denoiser(8).unwrap();
        assert!(ra.denoiser(24).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn legacy_shape_without_extras_is_single_entry_registry() {
        let dir = tmp("legacy");
        write_stub_artifacts(&dir, &[]).unwrap();
        // No `resolutions` key at all — the legacy manifest shape.
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .unwrap();
        assert!(!text.contains("resolutions"));
        let reg = ArtifactRegistry::load(&dir).unwrap();
        assert_eq!(reg.registered().len(), 1);
        assert!(!reg.is_registered(ResKey { h: 16, w: 32 }));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn drift_table_roundtrips_through_the_manifest() {
        use crate::device::OccupancySchedule;
        let dir = tmp("drift");
        let sched = OccupancySchedule::parse("0@0;0@0,0.6@4").unwrap();
        write_stub_artifacts_with_drift(&dir, &[], Some(&sched)).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.drift.as_ref(), Some(&sched));
        // Plain sets carry no drift table at all (legacy shape).
        let dir2 = tmp("nodrift");
        write_stub_artifacts(&dir2, &[]).unwrap();
        let text =
            std::fs::read_to_string(dir2.join("manifest.json")).unwrap();
        assert!(!text.contains("drift"));
        assert!(Manifest::load(&dir2).unwrap().drift.is_none());
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&dir2);
    }

    #[test]
    fn kv_gain_roundtrips_and_is_absent_by_default() {
        let dir = tmp("kvgain");
        write_stub_artifacts_full(&dir, &[], None, Some(0.05)).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.kv_gain, Some(0.05));
        // Plain sets carry no kv_gain key at all (legacy shape).
        let dir2 = tmp("nokvgain");
        write_stub_artifacts(&dir2, &[]).unwrap();
        let text =
            std::fs::read_to_string(dir2.join("manifest.json")).unwrap();
        assert!(!text.contains("kv_gain"));
        assert!(Manifest::load(&dir2).unwrap().kv_gain.is_none());
        // Out-of-range gains are rejected at write time.
        assert!(
            write_stub_artifacts_full(&dir, &[], None, Some(1.5)).is_err()
        );
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&dir2);
    }

    #[test]
    fn rejects_misaligned_native_and_duplicate_resolutions() {
        let dir = tmp("bad");
        assert!(write_stub_artifacts(&dir, &[(10, 32)]).is_err());
        assert!(write_stub_artifacts(&dir, &[(16, 31)]).is_err());
        // Writing a set the registry would refuse to load is caught
        // at write time.
        assert!(
            write_stub_artifacts(&dir, &[(LATENT_H, LATENT_W)]).is_err()
        );
        assert!(
            write_stub_artifacts(&dir, &[(16, 32), (16, 32)]).is_err()
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
