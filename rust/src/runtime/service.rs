//! Execution service: a dedicated thread owning the (non-Send) PJRT
//! client, fronted by a cloneable, thread-safe `ExecHandle`.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based, so it cannot cross
//! threads. All execution therefore funnels through one service thread
//! — which is also faithful to the substrate: a single physical CPU
//! "hosts" every simulated GPU, and the coordinator's heterogeneity
//! model (stretching / virtual clocks) lives *outside* the compute
//! call. Workers hold clones of the handle; each request carries its
//! own reply channel.
//!
//! Weights are loaded once inside the service, so per-step messages
//! carry only the step inputs (x patch, stale KV, scalars).

use std::sync::mpsc;
use std::thread::JoinHandle;

use crate::device::CostModel;
use crate::error::{Error, Result};
use crate::runtime::artifacts::Manifest;
use crate::runtime::client::{DenoiserInputs, DenoiserOutputs, Runtime};
use crate::runtime::tensor::Tensor;

enum Msg {
    Denoise {
        h: usize,
        x_patch: Tensor,
        kv_stale: Tensor,
        row_off: usize,
        t: f64,
        cond: Vec<f32>,
        reply: mpsc::Sender<Result<DenoiserOutputs>>,
    },
    DdimArtifact {
        x: Tensor,
        eps: Tensor,
        coef_x: f64,
        coef_eps: f64,
        reply: mpsc::Sender<Result<Tensor>>,
    },
    Features {
        x: Tensor,
        reply: mpsc::Sender<Result<(Vec<f32>, Vec<f32>, Vec<f32>)>>,
    },
    Calibrate {
        reps: usize,
        reply: mpsc::Sender<Result<CostModel>>,
    },
    Warm {
        keys: Vec<String>,
        reply: mpsc::Sender<Result<()>>,
    },
    Shutdown,
}

/// Cloneable, Send handle to the execution service.
#[derive(Clone)]
pub struct ExecHandle {
    tx: mpsc::Sender<Msg>,
    manifest: Manifest,
}

/// Owns the service thread; dropping shuts it down.
pub struct ExecService {
    handle: ExecHandle,
    join: Option<JoinHandle<()>>,
}

impl ExecService {
    /// Spawn the service: loads the manifest eagerly (errors early),
    /// builds the PJRT client + params inside the thread.
    pub fn spawn(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        // Feature check before the artifacts check: on a stub build the
        // missing backend is the real problem, whether or not
        // `make artifacts` has been run.
        if !cfg!(feature = "xla-backend") {
            return Err(Error::msg(crate::runtime::client::NO_BACKEND));
        }
        let manifest = Manifest::load(artifacts_dir)?;
        let (tx, rx) = mpsc::channel::<Msg>();
        let m2 = manifest.clone();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("pjrt-exec".into())
            .spawn(move || {
                let rt = match Runtime::new(m2) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let params = match rt.manifest().load_params() {
                    Ok(p) => p,
                    Err(e) => {
                        crate::log_error!("exec", "params load failed: {e}");
                        return;
                    }
                };
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Denoise {
                            h, x_patch, kv_stale, row_off, t, cond, reply,
                        } => {
                            let out = rt.denoise(
                                h,
                                &DenoiserInputs {
                                    params: &params,
                                    x_patch: &x_patch,
                                    kv_stale: &kv_stale,
                                    row_off,
                                    t,
                                    cond: &cond,
                                },
                            );
                            let _ = reply.send(out);
                        }
                        Msg::DdimArtifact { x, eps, coef_x, coef_eps, reply } => {
                            let _ = reply
                                .send(rt.ddim_update(&x, &eps, coef_x, coef_eps));
                        }
                        Msg::Features { x, reply } => {
                            let _ = reply.send(rt.features(&x));
                        }
                        Msg::Calibrate { reps, reply } => {
                            let _ = reply.send(CostModel::calibrate(&rt, reps));
                        }
                        Msg::Warm { keys, reply } => {
                            let _ = reply.send(rt.warm(&keys));
                        }
                        Msg::Shutdown => break,
                    }
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| Error::msg("exec service died during startup"))??;
        Ok(ExecService { handle: ExecHandle { tx, manifest }, join: Some(join) })
    }

    pub fn handle(&self) -> ExecHandle {
        self.handle.clone()
    }
}

impl Drop for ExecService {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl ExecHandle {
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn rpc<T>(
        &self,
        build: impl FnOnce(mpsc::Sender<Result<T>>) -> Msg,
    ) -> Result<T> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(build(reply))
            .map_err(|_| Error::msg("exec service gone"))?;
        rx.recv().map_err(|_| Error::msg("exec service dropped reply"))?
    }

    /// Execute one denoiser step (inputs are copied into the message).
    pub fn denoise(
        &self,
        h: usize,
        x_patch: &Tensor,
        kv_stale: &Tensor,
        row_off: usize,
        t: f64,
        cond: &[f32],
    ) -> Result<DenoiserOutputs> {
        self.rpc(|reply| Msg::Denoise {
            h,
            x_patch: x_patch.clone(),
            kv_stale: kv_stale.clone(),
            row_off,
            t,
            cond: cond.to_vec(),
            reply,
        })
    }

    /// AOT'd DDIM-update artifact (cross-validation path).
    pub fn ddim_artifact(
        &self,
        x: &Tensor,
        eps: &Tensor,
        coef_x: f64,
        coef_eps: f64,
    ) -> Result<Tensor> {
        self.rpc(|reply| Msg::DdimArtifact {
            x: x.clone(),
            eps: eps.clone(),
            coef_x,
            coef_eps,
            reply,
        })
    }

    /// Feature extractor (LPIPS/FID proxies).
    pub fn features(&self, x: &Tensor) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        self.rpc(|reply| Msg::Features { x: x.clone(), reply })
    }

    /// Calibrate the per-step cost model on the real substrate.
    pub fn calibrate(&self, reps: usize) -> Result<CostModel> {
        self.rpc(|reply| Msg::Calibrate { reps, reply })
    }

    /// Pre-compile artifacts off the request path.
    pub fn warm(&self, keys: &[String]) -> Result<()> {
        self.rpc(|reply| Msg::Warm { keys: keys.to_vec(), reply })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts() -> Option<PathBuf> {
        if !cfg!(feature = "xla-backend") {
            eprintln!("skipping: built without xla-backend");
            return None;
        }
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            Some(dir)
        } else {
            eprintln!("skipping: artifacts not built");
            None
        }
    }

    #[test]
    fn handle_works_across_threads() {
        let Some(dir) = artifacts() else { return };
        let svc = ExecService::spawn(dir).unwrap();
        let h = svc.handle();
        let model = h.manifest().model.clone();
        let mut handles = Vec::new();
        for i in 0..3 {
            let h = h.clone();
            let model = model.clone();
            handles.push(std::thread::spawn(move || {
                let x = Tensor::zeros(&[8, model.latent_w, model.latent_c]);
                let kv = Tensor::zeros(&model.kv_shape());
                let cond = vec![0.1f32 * i as f32; model.dim];
                h.denoise(8, &x, &kv, 0, 100.0, &cond).unwrap()
            }));
        }
        for th in handles {
            let out = th.join().unwrap();
            assert_eq!(out.eps_patch.shape, vec![8, 32, 4]);
        }
    }

    #[test]
    fn spawn_fails_cleanly_on_missing_artifacts() {
        assert!(ExecService::spawn("/nonexistent").is_err());
    }
}
