//! Execution service: a dedicated thread owning the (non-Send)
//! execution backend, fronted by a cloneable, thread-safe `ExecHandle`.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based, so it cannot cross
//! threads. All execution therefore funnels through one service thread
//! — which is also faithful to the substrate: a single physical CPU
//! "hosts" every simulated GPU, and the coordinator's heterogeneity
//! model (stretching / virtual clocks) lives *outside* the compute
//! call. Workers hold clones of the handle; each request carries its
//! own reply channel.
//!
//! Two backends sit behind the service:
//!
//! * the **real PJRT runtime** (feature `xla-backend`) for genuine
//!   AOT'd HLO artifacts;
//! * the **deterministic stub backend** for synthetic artifact sets
//!   whose manifest carries `"stub": true` (see
//!   [`crate::runtime::stubgen`]) — available on every build, so the
//!   whole engine runs end-to-end offline.
//!
//! Execution is resolution-keyed: requests name the [`ResKey`] whose
//! artifact set they run against (the registry loads non-native
//! resolutions lazily), and the legacy single-resolution entry points
//! forward to the native key.
//!
//! Weights are loaded once inside the service, so per-step messages
//! carry only the step inputs (x patch, stale KV, scalars).

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::device::CostModel;
use crate::error::{Error, Result};
use crate::runtime::artifacts::{ArtifactRegistry, Manifest, ResKey};
#[cfg(feature = "xla-backend")]
use crate::runtime::client::Runtime;
use crate::runtime::client::{DenoiserInputs, DenoiserOutputs};
use crate::runtime::stub_exec::StubExec;
use crate::runtime::tensor::Tensor;

enum Msg {
    Denoise {
        res: ResKey,
        h: usize,
        x_patch: Tensor,
        kv_stale: Tensor,
        row_off: usize,
        t: f64,
        cond: Vec<f32>,
        reply: mpsc::Sender<Result<DenoiserOutputs>>,
    },
    DdimArtifact {
        x: Tensor,
        eps: Tensor,
        coef_x: f64,
        coef_eps: f64,
        reply: mpsc::Sender<Result<Tensor>>,
    },
    Features {
        x: Tensor,
        reply: mpsc::Sender<Result<(Vec<f32>, Vec<f32>, Vec<f32>)>>,
    },
    Calibrate {
        reps: usize,
        reply: mpsc::Sender<Result<CostModel>>,
    },
    Warm {
        res: ResKey,
        heights: Vec<usize>,
        reply: mpsc::Sender<Result<()>>,
    },
    Shutdown,
}

/// The service thread's execution backend.
enum Backend {
    #[cfg(feature = "xla-backend")]
    Real(Runtime),
    Stub(StubExec),
}

impl Backend {
    fn open(registry: Arc<ArtifactRegistry>) -> Result<Backend> {
        if registry.manifest().stub {
            return Ok(Backend::Stub(StubExec::new(registry)?));
        }
        #[cfg(feature = "xla-backend")]
        {
            Ok(Backend::Real(Runtime::new(registry)?))
        }
        #[cfg(not(feature = "xla-backend"))]
        {
            Err(Error::msg(crate::runtime::client::NO_BACKEND))
        }
    }

    fn manifest(&self) -> &Manifest {
        match self {
            #[cfg(feature = "xla-backend")]
            Backend::Real(rt) => rt.manifest(),
            Backend::Stub(s) => s.manifest(),
        }
    }

    fn denoise(
        &self,
        res: ResKey,
        h: usize,
        inp: &DenoiserInputs<'_>,
    ) -> Result<DenoiserOutputs> {
        match self {
            #[cfg(feature = "xla-backend")]
            Backend::Real(rt) => rt.denoise_at(res, h, inp),
            Backend::Stub(s) => s.denoise(res, h, inp),
        }
    }

    fn ddim_update(
        &self,
        x: &Tensor,
        eps: &Tensor,
        coef_x: f64,
        coef_eps: f64,
    ) -> Result<Tensor> {
        match self {
            #[cfg(feature = "xla-backend")]
            Backend::Real(rt) => rt.ddim_update(x, eps, coef_x, coef_eps),
            Backend::Stub(s) => s.ddim_update(x, eps, coef_x, coef_eps),
        }
    }

    fn features(&self, x: &Tensor) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        match self {
            #[cfg(feature = "xla-backend")]
            Backend::Real(rt) => rt.features(x),
            Backend::Stub(s) => s.features(x),
        }
    }

    fn calibrate(&self, reps: usize) -> Result<CostModel> {
        match self {
            #[cfg(feature = "xla-backend")]
            Backend::Real(rt) => CostModel::calibrate(rt, reps),
            Backend::Stub(s) => s.calibrate(reps),
        }
    }

    fn warm(&self, res: ResKey, heights: &[usize]) -> Result<()> {
        match self {
            #[cfg(feature = "xla-backend")]
            Backend::Real(rt) => rt.warm_at(res, heights),
            Backend::Stub(s) => s.warm(res, heights),
        }
    }
}

/// Cloneable, Send handle to the execution service.
#[derive(Clone)]
pub struct ExecHandle {
    tx: mpsc::Sender<Msg>,
    registry: Arc<ArtifactRegistry>,
}

/// Owns the service thread; dropping shuts it down.
pub struct ExecService {
    handle: ExecHandle,
    join: Option<JoinHandle<()>>,
}

impl ExecService {
    /// Spawn the service: loads the artifact registry eagerly (errors
    /// early), builds the backend + params inside the thread.
    ///
    /// Backend selection: stub manifests always run on the
    /// deterministic stub backend (any build); real manifests need the
    /// `xla-backend` feature. On a feature-less build the missing
    /// backend is reported before artifact problems — the actual fix
    /// is the build flag, whether or not `make artifacts` has run.
    pub fn spawn(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let registry = match ArtifactRegistry::load(&artifacts_dir) {
            Ok(r) => Arc::new(r),
            Err(e) => {
                // On a feature-less build with *no manifest at all*,
                // the missing backend is the actual problem ("run make
                // artifacts" would not help). But if a manifest exists
                // and fails to load — a corrupt stub set, a stale
                // resolutions table — report that real error: default
                // builds are fully executable via stub artifacts, so
                // "rebuild with --features xla-backend" would be wrong
                // advice.
                let have_manifest = artifacts_dir
                    .as_ref()
                    .join("manifest.json")
                    .exists();
                if !cfg!(feature = "xla-backend") && !have_manifest {
                    return Err(Error::msg(
                        crate::runtime::client::NO_BACKEND,
                    ));
                }
                return Err(e);
            }
        };
        if !registry.manifest().stub && !cfg!(feature = "xla-backend") {
            return Err(Error::msg(crate::runtime::client::NO_BACKEND));
        }
        let (tx, rx) = mpsc::channel::<Msg>();
        let reg2 = Arc::clone(&registry);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("pjrt-exec".into())
            .spawn(move || {
                let backend = match Backend::open(reg2) {
                    Ok(b) => {
                        let _ = ready_tx.send(Ok(()));
                        b
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let params = match backend.manifest().load_params() {
                    Ok(p) => p,
                    Err(e) => {
                        crate::log_error!("exec", "params load failed: {e}");
                        return;
                    }
                };
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Denoise {
                            res,
                            h,
                            x_patch,
                            kv_stale,
                            row_off,
                            t,
                            cond,
                            reply,
                        } => {
                            let out = backend.denoise(
                                res,
                                h,
                                &DenoiserInputs {
                                    params: &params,
                                    x_patch: &x_patch,
                                    kv_stale: &kv_stale,
                                    row_off,
                                    t,
                                    cond: &cond,
                                },
                            );
                            let _ = reply.send(out);
                        }
                        Msg::DdimArtifact { x, eps, coef_x, coef_eps, reply } => {
                            let _ = reply.send(
                                backend.ddim_update(&x, &eps, coef_x, coef_eps),
                            );
                        }
                        Msg::Features { x, reply } => {
                            let _ = reply.send(backend.features(&x));
                        }
                        Msg::Calibrate { reps, reply } => {
                            let _ = reply.send(backend.calibrate(reps));
                        }
                        Msg::Warm { res, heights, reply } => {
                            let _ = reply.send(backend.warm(res, &heights));
                        }
                        Msg::Shutdown => break,
                    }
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| Error::msg("exec service died during startup"))??;
        Ok(ExecService {
            handle: ExecHandle { tx, registry },
            join: Some(join),
        })
    }

    pub fn handle(&self) -> ExecHandle {
        self.handle.clone()
    }
}

impl Drop for ExecService {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl ExecHandle {
    /// The base (native-resolution) manifest.
    pub fn manifest(&self) -> &Manifest {
        self.registry.manifest()
    }

    /// The resolution-keyed artifact registry.
    pub fn registry(&self) -> &Arc<ArtifactRegistry> {
        &self.registry
    }

    fn rpc<T>(
        &self,
        build: impl FnOnce(mpsc::Sender<Result<T>>) -> Msg,
    ) -> Result<T> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(build(reply))
            .map_err(|_| Error::msg("exec service gone"))?;
        rx.recv().map_err(|_| Error::msg("exec service dropped reply"))?
    }

    /// Execute one native-resolution denoiser step (inputs are copied
    /// into the message).
    pub fn denoise(
        &self,
        h: usize,
        x_patch: &Tensor,
        kv_stale: &Tensor,
        row_off: usize,
        t: f64,
        cond: &[f32],
    ) -> Result<DenoiserOutputs> {
        self.denoise_at(
            self.registry.native_key(),
            h,
            x_patch,
            kv_stale,
            row_off,
            t,
            cond,
        )
    }

    /// Execute one denoiser step against a registered resolution's
    /// artifact set.
    #[allow(clippy::too_many_arguments)]
    pub fn denoise_at(
        &self,
        res: ResKey,
        h: usize,
        x_patch: &Tensor,
        kv_stale: &Tensor,
        row_off: usize,
        t: f64,
        cond: &[f32],
    ) -> Result<DenoiserOutputs> {
        self.rpc(|reply| Msg::Denoise {
            res,
            h,
            x_patch: x_patch.clone(),
            kv_stale: kv_stale.clone(),
            row_off,
            t,
            cond: cond.to_vec(),
            reply,
        })
    }

    /// AOT'd DDIM-update artifact (cross-validation path).
    pub fn ddim_artifact(
        &self,
        x: &Tensor,
        eps: &Tensor,
        coef_x: f64,
        coef_eps: f64,
    ) -> Result<Tensor> {
        self.rpc(|reply| Msg::DdimArtifact {
            x: x.clone(),
            eps: eps.clone(),
            coef_x,
            coef_eps,
            reply,
        })
    }

    /// Feature extractor (LPIPS/FID proxies).
    pub fn features(&self, x: &Tensor) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        self.rpc(|reply| Msg::Features { x: x.clone(), reply })
    }

    /// Calibrate the per-step cost model on the real substrate.
    pub fn calibrate(&self, reps: usize) -> Result<CostModel> {
        self.rpc(|reply| Msg::Calibrate { reps, reply })
    }

    /// Pre-compile a resolution's denoisers off the request path.
    pub fn warm_res(&self, res: ResKey, heights: &[usize]) -> Result<()> {
        self.rpc(|reply| Msg::Warm {
            res,
            heights: heights.to_vec(),
            reply,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::stubgen;
    use std::path::PathBuf;

    fn artifacts() -> Option<PathBuf> {
        if !cfg!(feature = "xla-backend") {
            eprintln!("skipping: built without xla-backend");
            return None;
        }
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            Some(dir)
        } else {
            eprintln!("skipping: artifacts not built");
            None
        }
    }

    #[test]
    fn handle_works_across_threads() {
        let Some(dir) = artifacts() else { return };
        let svc = ExecService::spawn(dir).unwrap();
        let h = svc.handle();
        let model = h.manifest().model.clone();
        let mut handles = Vec::new();
        for i in 0..3 {
            let h = h.clone();
            let model = model.clone();
            handles.push(std::thread::spawn(move || {
                let x = Tensor::zeros(&[8, model.latent_w, model.latent_c]);
                let kv = Tensor::zeros(&model.kv_shape());
                let cond = vec![0.1f32 * i as f32; model.dim];
                h.denoise(8, &x, &kv, 0, 100.0, &cond).unwrap()
            }));
        }
        for th in handles {
            let out = th.join().unwrap();
            assert_eq!(out.eps_patch.shape, vec![8, 32, 4]);
        }
    }

    #[test]
    fn spawn_fails_cleanly_on_missing_artifacts() {
        assert!(ExecService::spawn("/nonexistent").is_err());
    }

    /// The stub backend serves any build: spawn over synthetic
    /// artifacts, execute at native and registered non-native
    /// resolutions, and get deterministic outputs — no PJRT, no
    /// feature flag, no python.
    #[test]
    fn stub_backend_executes_every_registered_resolution() {
        let dir = std::env::temp_dir()
            .join(format!("stadi-svc-stub-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        stubgen::write_stub_artifacts(
            &dir,
            stubgen::DEFAULT_EXTRA_RESOLUTIONS,
        )
        .unwrap();
        let svc = ExecService::spawn(&dir).unwrap();
        let h = svc.handle();
        assert!(h.manifest().stub);
        for res in h.registry().registered() {
            let ra = h.registry().get(res).unwrap();
            let m = ra.model.clone();
            let ph = m.row_granularity;
            h.warm_res(res, &[ph]).unwrap();
            let x = Tensor::zeros(&[ph, m.latent_w, m.latent_c]);
            let kv = Tensor::zeros(&m.kv_shape());
            let cond = vec![0.5f32; m.dim];
            let a = h.denoise_at(res, ph, &x, &kv, 0, 250.0, &cond).unwrap();
            let b = h.denoise_at(res, ph, &x, &kv, 0, 250.0, &cond).unwrap();
            assert_eq!(a.eps_patch, b.eps_patch, "stub not deterministic");
            assert_eq!(
                a.eps_patch.shape,
                vec![ph, m.latent_w, m.latent_c]
            );
        }
        // Unregistered resolutions fail with a typed artifact error.
        let bogus = crate::runtime::artifacts::ResKey { h: 24, w: 32 };
        let m = h.manifest().model.clone();
        let x = Tensor::zeros(&[4, m.latent_w, m.latent_c]);
        let kv = Tensor::zeros(&m.kv_shape());
        let cond = vec![0.0f32; m.dim];
        let e = h
            .denoise_at(bogus, 4, &x, &kv, 0, 1.0, &cond)
            .unwrap_err();
        assert!(e.to_string().contains("not registered"), "{e}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
