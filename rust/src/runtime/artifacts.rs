//! Artifact manifest: the ABI between `python/compile/aot.py` and this
//! runtime. Parses `artifacts/manifest.json`, validates file presence
//! and sizes, and loads `params.bin`.
//!
//! Multi-resolution artifacts: a manifest may carry a `resolutions`
//! table of additional AOT'd latent sizes. [`ArtifactRegistry`] wraps
//! the base [`Manifest`] (the *native* resolution, parsed exactly as
//! before — legacy single-resolution manifests load as a one-entry
//! registry) and lazily validates/loads the extra resolutions behind
//! an `RwLock`, holding at most a bounded number resident (LRU) so a
//! long-running server doesn't keep every compiled size in memory.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::{Arc, RwLock};

use crate::error::{Error, Result};
use crate::util::json::{self, Value};

/// Model geometry as recorded by the AOT step (mirror of
/// `python/compile/config.py::ModelConfig`).
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub latent_h: usize,
    pub latent_w: usize,
    pub latent_c: usize,
    pub patch: usize,
    pub dim: usize,
    pub heads: usize,
    pub layers: usize,
    pub temb_dim: usize,
    pub row_granularity: usize,
    pub tokens_full: usize,
    pub param_count: usize,
    pub params_seed: u64,
}

impl ModelInfo {
    pub fn tokens_for_rows(&self, rows: usize) -> usize {
        assert_eq!(rows % self.patch, 0);
        (rows / self.patch) * (self.latent_w / self.patch)
    }

    /// This model re-based onto another latent resolution: everything
    /// but the latent geometry (and the token count it implies) is
    /// shared — the weights, layer stack and patch size are the same
    /// network compiled for a different canvas.
    pub fn with_resolution(&self, latent_h: usize, latent_w: usize) -> ModelInfo {
        ModelInfo {
            latent_h,
            latent_w,
            tokens_full: (latent_h / self.patch) * (latent_w / self.patch),
            ..self.clone()
        }
    }

    /// Shape of one latent image.
    pub fn latent_shape(&self) -> Vec<usize> {
        vec![self.latent_h, self.latent_w, self.latent_c]
    }

    /// Shape of the full per-layer KV buffer stack [L, T_full, 2D].
    pub fn kv_shape(&self) -> Vec<usize> {
        vec![self.layers, self.tokens_full, 2 * self.dim]
    }
}

/// Schedule parameters recorded by AOT (mirror of ScheduleConfig).
#[derive(Debug, Clone)]
pub struct ScheduleInfo {
    pub train_steps: usize,
    pub beta_start: f64,
    pub beta_end: f64,
}

/// One input/output slot of an artifact.
#[derive(Debug, Clone)]
pub struct Slot {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One AOT'd HLO-text artifact.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub key: String,
    pub file: PathBuf,
    pub inputs: Vec<Slot>,
    pub outputs: Vec<Slot>,
    pub bytes: usize,
}

/// Parsed manifest + resolved paths.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelInfo,
    pub schedule: ScheduleInfo,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
    /// Patch heights with a denoiser artifact, ascending.
    pub patch_heights: Vec<usize>,
    /// True for synthetic artifact sets written by
    /// [`crate::runtime::stubgen`]: their "HLO" files are
    /// placeholders executed by the deterministic stub backend, never
    /// by PJRT. Absent (false) in every real manifest, so legacy
    /// manifests parse unchanged.
    pub stub: bool,
    /// Optional deterministic per-device occupancy schedule (`"drift"`
    /// table, written by stubgen for drift-injection tests): the
    /// engine's virtual clocks replay it so mid-request speed drift is
    /// byte-reproducible offline. `STADI_DRIFT` overrides it; absent
    /// in every real manifest.
    pub drift: Option<crate::device::OccupancySchedule>,
    /// Optional kv-context coupling gain (`"kv_gain"` key, written by
    /// stubgen for halo quality-gate tests): the stub backend mixes
    /// this fraction of the stale KV context into each eps sample, so
    /// displaced-halo staleness produces *measurable* (but bounded)
    /// numeric drift instead of none. Absent (and treated as 0.0 —
    /// the exact legacy arithmetic) in every real manifest.
    pub kv_gain: Option<f64>,
}

fn parse_slots(v: &Value) -> Result<Vec<Slot>> {
    v.as_arr()?
        .iter()
        .map(|s| {
            Ok(Slot {
                name: s.get("name")?.as_str()?.to_string(),
                shape: s.get("shape")?.usizes()?,
                dtype: s.get("dtype")?.as_str()?.to_string(),
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        if !path.exists() {
            return Err(Error::Artifact(format!(
                "{} not found — run `make artifacts` first",
                path.display()
            )));
        }
        let v = json::from_file(&path)?;

        let m = v.get("model")?;
        let model = ModelInfo {
            latent_h: m.get("latent_h")?.as_usize()?,
            latent_w: m.get("latent_w")?.as_usize()?,
            latent_c: m.get("latent_c")?.as_usize()?,
            patch: m.get("patch")?.as_usize()?,
            dim: m.get("dim")?.as_usize()?,
            heads: m.get("heads")?.as_usize()?,
            layers: m.get("layers")?.as_usize()?,
            temb_dim: m.get("temb_dim")?.as_usize()?,
            row_granularity: m.get("row_granularity")?.as_usize()?,
            tokens_full: m.get("tokens_full")?.as_usize()?,
            param_count: m.get("param_count")?.as_usize()?,
            params_seed: m.get("params_seed")?.as_i64()? as u64,
        };
        let s = v.get("schedule")?;
        let schedule = ScheduleInfo {
            train_steps: s.get("train_steps")?.as_usize()?,
            beta_start: s.get("beta_start")?.as_f64()?,
            beta_end: s.get("beta_end")?.as_f64()?,
        };

        let mut artifacts = BTreeMap::new();
        let mut patch_heights = Vec::new();
        for (key, a) in v.get("artifacts")?.as_obj()?.iter() {
            let file = dir.join(a.get("file")?.as_str()?);
            let bytes = a.get("bytes")?.as_usize()?;
            if !file.exists() {
                return Err(Error::Artifact(format!(
                    "artifact file missing: {}",
                    file.display()
                )));
            }
            let actual = std::fs::metadata(&file)?.len() as usize;
            if actual != bytes {
                return Err(Error::Artifact(format!(
                    "{}: size {actual} != manifest {bytes} (stale \
                     artifacts? re-run `make artifacts`)",
                    file.display()
                )));
            }
            if let Some(hs) = key.strip_prefix("denoiser_h") {
                patch_heights.push(hs.parse::<usize>().map_err(|_| {
                    Error::Artifact(format!("bad artifact key {key}"))
                })?);
            }
            artifacts.insert(
                key.clone(),
                ArtifactInfo {
                    key: key.clone(),
                    file,
                    inputs: parse_slots(a.get("inputs")?)?,
                    outputs: parse_slots(a.get("outputs")?)?,
                    bytes,
                },
            );
        }
        patch_heights.sort_unstable();
        if patch_heights.is_empty() {
            return Err(Error::Artifact("no denoiser artifacts".into()));
        }
        let stub = match v.get_opt("stub") {
            Some(x) => x.as_bool()?,
            None => false,
        };
        let drift = match v.get_opt("drift") {
            Some(x) => {
                Some(crate::device::OccupancySchedule::from_json(x)?)
            }
            None => None,
        };
        let kv_gain = match v.get_opt("kv_gain") {
            Some(x) => {
                let g = x.as_f64()?;
                if !(0.0..=1.0).contains(&g) {
                    return Err(Error::Artifact(format!(
                        "kv_gain {g} outside [0, 1]"
                    )));
                }
                Some(g)
            }
            None => None,
        };

        Ok(Manifest {
            dir,
            model,
            schedule,
            artifacts,
            patch_heights,
            stub,
            drift,
            kv_gain,
        })
    }

    pub fn artifact(&self, key: &str) -> Result<&ArtifactInfo> {
        self.artifacts
            .get(key)
            .ok_or_else(|| Error::Artifact(format!("no artifact {key:?}")))
    }

    pub fn denoiser(&self, h: usize) -> Result<&ArtifactInfo> {
        self.artifact(&format!("denoiser_h{h}"))
    }

    /// Load the flat f32 weight vector, validating its length.
    pub fn load_params(&self) -> Result<Vec<f32>> {
        let path = self.dir.join("params.bin");
        let bytes = std::fs::read(&path)?;
        if bytes.len() != self.model.param_count * 4 {
            return Err(Error::Artifact(format!(
                "params.bin: {} bytes, expected {}",
                bytes.len(),
                self.model.param_count * 4
            )));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Read a golden JSON file dumped by aot.py.
    pub fn golden(&self, name: &str) -> Result<Value> {
        json::from_file(&self.dir.join("golden").join(name))
    }
}

// --- Resolution-keyed artifact registry ------------------------------

/// Key of one compiled resolution, in latent units (rows x cols).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ResKey {
    pub h: usize,
    pub w: usize,
}

impl ResKey {
    pub fn of_model(m: &ModelInfo) -> ResKey {
        ResKey { h: m.latent_h, w: m.latent_w }
    }
}

impl std::fmt::Display for ResKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}", self.h, self.w)
    }
}

/// One resolution's artifact set, ready to execute: the model geometry
/// re-based onto that latent size plus the denoiser artifacts compiled
/// for it.
#[derive(Debug, Clone)]
pub struct ResolutionArtifacts {
    pub key: ResKey,
    pub model: ModelInfo,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
    /// Patch heights with a denoiser artifact, ascending.
    pub patch_heights: Vec<usize>,
    /// patch height -> artifact key.
    denoisers: BTreeMap<usize, String>,
}

impl ResolutionArtifacts {
    pub fn denoiser(&self, h: usize) -> Result<&ArtifactInfo> {
        let key = self.denoiser_key(h)?;
        self.artifacts
            .get(key)
            .ok_or_else(|| Error::Artifact(format!("no artifact {key:?}")))
    }

    pub fn denoiser_key(&self, h: usize) -> Result<&str> {
        self.denoisers.get(&h).map(String::as_str).ok_or_else(|| {
            Error::Artifact(format!(
                "resolution {}: no denoiser artifact for patch height \
                 {h} (have {:?})",
                self.key, self.patch_heights
            ))
        })
    }
}

/// A not-yet-validated resolution entry from the manifest's
/// `resolutions` table: file presence/sizes are checked lazily on
/// first [`ArtifactRegistry::get`], not at registry load.
#[derive(Debug, Clone)]
struct PendingResolution {
    key: ResKey,
    artifacts: Vec<PendingArtifact>,
}

#[derive(Debug, Clone)]
struct PendingArtifact {
    key: String,
    file: PathBuf,
    bytes: usize,
    inputs: Vec<Slot>,
    outputs: Vec<Slot>,
    patch_h: Option<usize>,
}

/// Cumulative load/evict counters of a registry (tests and ops
/// dashboards; `resident` excludes the always-resident native set).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RegistryStats {
    pub resident: usize,
    pub loads: u64,
    pub evictions: u64,
}

struct RegistryState {
    loaded: HashMap<ResKey, Arc<ResolutionArtifacts>>,
    /// Least-recently-used order, front = next eviction victim.
    lru: VecDeque<ResKey>,
    loads: u64,
    evictions: u64,
}

/// Default bound on resident non-native resolutions: traffic mixes
/// rarely exceed a handful of live sizes.
pub const DEFAULT_RESOLUTION_CAPACITY: usize = 4;

/// Resolution-keyed artifact registry.
///
/// The *native* resolution is the base [`Manifest`] (always resident,
/// never evicted — it is the legacy single-resolution path, byte-for-
/// byte). Extra resolutions declared in the manifest's `resolutions`
/// table validate and load lazily on first use; at most `capacity` of
/// them stay resident (LRU) so a long-running server over a wide size
/// mix doesn't accumulate every compiled size.
pub struct ArtifactRegistry {
    manifest: Manifest,
    native: Arc<ResolutionArtifacts>,
    pending: BTreeMap<ResKey, PendingResolution>,
    capacity: usize,
    state: RwLock<RegistryState>,
}

impl ArtifactRegistry {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        Self::with_capacity(dir, DEFAULT_RESOLUTION_CAPACITY)
    }

    pub fn with_capacity(
        dir: impl AsRef<Path>,
        capacity: usize,
    ) -> Result<Self> {
        let manifest = Manifest::load(&dir)?;
        let native = Arc::new(native_resolution(&manifest));
        let v = json::from_file(&manifest.dir.join("manifest.json"))?;
        let mut pending = BTreeMap::new();
        if let Some(table) = v.get_opt("resolutions") {
            for (label, r) in table.as_obj()?.iter() {
                let p = parse_resolution(&manifest, label, r)?;
                if p.key == native.key {
                    return Err(Error::Artifact(format!(
                        "resolution {label} duplicates the native \
                         resolution {}",
                        native.key
                    )));
                }
                if pending.insert(p.key, p).is_some() {
                    return Err(Error::Artifact(format!(
                        "duplicate resolution entry {label}"
                    )));
                }
            }
        }
        Ok(ArtifactRegistry {
            manifest,
            native,
            pending,
            capacity: capacity.max(1),
            state: RwLock::new(RegistryState {
                loaded: HashMap::new(),
                lru: VecDeque::new(),
                loads: 0,
                evictions: 0,
            }),
        })
    }

    /// The base (native-resolution) manifest, parsed exactly as the
    /// legacy single-resolution loader did.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn native_key(&self) -> ResKey {
        self.native.key
    }

    pub fn native(&self) -> Arc<ResolutionArtifacts> {
        Arc::clone(&self.native)
    }

    /// True when `key` has compiled artifacts (native or declared in
    /// the `resolutions` table) — the admission-time question.
    pub fn is_registered(&self, key: ResKey) -> bool {
        key == self.native.key || self.pending.contains_key(&key)
    }

    /// True when `key`'s artifact set is currently resident (native is
    /// always resident). The PJRT runtime uses this to prune compiled
    /// executables for evicted resolutions, so the LRU cap bounds the
    /// heavyweight objects too, not just the metadata.
    pub fn is_resident(&self, key: ResKey) -> bool {
        key == self.native.key
            || self.state.read().unwrap().loaded.contains_key(&key)
    }

    /// Every registered resolution, native first then ascending.
    pub fn registered(&self) -> Vec<ResKey> {
        let mut v = vec![self.native.key];
        v.extend(self.pending.keys().copied());
        v
    }

    pub fn stats(&self) -> RegistryStats {
        let st = self.state.read().unwrap();
        RegistryStats {
            resident: st.loaded.len(),
            loads: st.loads,
            evictions: st.evictions,
        }
    }

    /// Fetch a resolution's artifact set, validating and loading it on
    /// first use. The native resolution never takes the lock.
    pub fn get(&self, key: ResKey) -> Result<Arc<ResolutionArtifacts>> {
        if key == self.native.key {
            return Ok(Arc::clone(&self.native));
        }
        {
            let mut st = self.state.write().unwrap();
            if let Some(ra) = st.loaded.get(&key) {
                let ra = Arc::clone(ra);
                touch_lru(&mut st.lru, key);
                return Ok(ra);
            }
        }
        let pending = self.pending.get(&key).ok_or_else(|| {
            Error::Artifact(format!(
                "resolution {key} not registered (registered: {})",
                self.registered()
                    .iter()
                    .map(ToString::to_string)
                    .collect::<Vec<_>>()
                    .join(", ")
            ))
        })?;
        // Validate files outside the lock (IO); two threads racing a
        // cold resolution just validate twice.
        let ra = Arc::new(load_resolution(&self.manifest, pending)?);
        let mut st = self.state.write().unwrap();
        if !st.loaded.contains_key(&key) {
            if st.loaded.len() >= self.capacity {
                if let Some(old) = st.lru.pop_front() {
                    st.loaded.remove(&old);
                    st.evictions += 1;
                }
            }
            st.loaded.insert(key, Arc::clone(&ra));
            st.lru.push_back(key);
            st.loads += 1;
            Ok(ra)
        } else {
            let ra = Arc::clone(&st.loaded[&key]);
            touch_lru(&mut st.lru, key);
            Ok(ra)
        }
    }
}

fn touch_lru(lru: &mut VecDeque<ResKey>, key: ResKey) {
    if let Some(pos) = lru.iter().position(|&k| k == key) {
        lru.remove(pos);
        lru.push_back(key);
    }
}

/// The base manifest as a resolution entry (artifact keys
/// `denoiser_h{h}` — the legacy naming, untouched).
fn native_resolution(m: &Manifest) -> ResolutionArtifacts {
    ResolutionArtifacts {
        key: ResKey::of_model(&m.model),
        model: m.model.clone(),
        artifacts: m.artifacts.clone(),
        patch_heights: m.patch_heights.clone(),
        denoisers: m
            .patch_heights
            .iter()
            .map(|&h| (h, format!("denoiser_h{h}")))
            .collect(),
    }
}

/// Parse one `resolutions` table entry, validating its geometry
/// against the base model (`tokens_full` and `kv_shape` are recorded
/// redundantly in the manifest precisely so a stale AOT run fails
/// loudly here instead of shipping wrong-shaped buffers).
fn parse_resolution(
    m: &Manifest,
    label: &str,
    v: &Value,
) -> Result<PendingResolution> {
    let h = v.get("latent_h")?.as_usize()?;
    let w = v.get("latent_w")?.as_usize()?;
    let model = &m.model;
    if h == 0
        || w == 0
        || h % model.row_granularity != 0
        || h % model.patch != 0
        || w % model.patch != 0
    {
        return Err(Error::Artifact(format!(
            "resolution {label}: latent {h}x{w} must be positive, \
             row-granularity-aligned ({}) and patch-aligned ({})",
            model.row_granularity, model.patch
        )));
    }
    let tokens_full = v.get("tokens_full")?.as_usize()?;
    let want_tokens = (h / model.patch) * (w / model.patch);
    if tokens_full != want_tokens {
        return Err(Error::Artifact(format!(
            "resolution {label}: tokens_full {tokens_full} != derived \
             {want_tokens} (stale resolutions table?)"
        )));
    }
    let kv_shape = v.get("kv_shape")?.usizes()?;
    let want_kv = vec![model.layers, tokens_full, 2 * model.dim];
    if kv_shape != want_kv {
        return Err(Error::Artifact(format!(
            "resolution {label}: kv_shape {kv_shape:?} != derived \
             {want_kv:?}"
        )));
    }
    let mut artifacts = Vec::new();
    for (key, a) in v.get("artifacts")?.as_obj()?.iter() {
        artifacts.push(PendingArtifact {
            key: key.clone(),
            file: m.dir.join(a.get("file")?.as_str()?),
            bytes: a.get("bytes")?.as_usize()?,
            inputs: parse_slots(a.get("inputs")?)?,
            outputs: parse_slots(a.get("outputs")?)?,
            patch_h: match a.get_opt("patch_h") {
                Some(x) => Some(x.as_usize()?),
                None => None,
            },
        });
    }
    if !artifacts.iter().any(|a| a.patch_h.is_some()) {
        return Err(Error::Artifact(format!(
            "resolution {label}: no denoiser artifacts (entries need a \
             patch_h field)"
        )));
    }
    Ok(PendingResolution { key: ResKey { h, w }, artifacts })
}

/// Validate one pending resolution's files (presence + sizes, same
/// contract as the base manifest) and assemble its artifact set.
fn load_resolution(
    m: &Manifest,
    p: &PendingResolution,
) -> Result<ResolutionArtifacts> {
    let mut artifacts = BTreeMap::new();
    let mut denoisers = BTreeMap::new();
    let mut patch_heights = Vec::new();
    for a in &p.artifacts {
        if !a.file.exists() {
            return Err(Error::Artifact(format!(
                "artifact file missing: {}",
                a.file.display()
            )));
        }
        let actual = std::fs::metadata(&a.file)?.len() as usize;
        if actual != a.bytes {
            return Err(Error::Artifact(format!(
                "{}: size {actual} != manifest {} (stale artifacts? \
                 re-run `make artifacts`)",
                a.file.display(),
                a.bytes
            )));
        }
        if let Some(h) = a.patch_h {
            patch_heights.push(h);
            denoisers.insert(h, a.key.clone());
        }
        artifacts.insert(
            a.key.clone(),
            ArtifactInfo {
                key: a.key.clone(),
                file: a.file.clone(),
                inputs: a.inputs.clone(),
                outputs: a.outputs.clone(),
                bytes: a.bytes,
            },
        );
    }
    patch_heights.sort_unstable();
    Ok(ResolutionArtifacts {
        key: p.key,
        model: m.model.with_resolution(p.key.h, p.key.w),
        artifacts,
        patch_heights,
        denoisers,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        // Tests run from the crate root.
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_manifest_and_params() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(artifacts_dir()).unwrap();
        assert_eq!(m.model.latent_h, 32);
        assert!(m.patch_heights.contains(&32));
        assert!(m.patch_heights.contains(&8));
        let params = m.load_params().unwrap();
        assert_eq!(params.len(), m.model.param_count);
        // Non-degenerate weights.
        assert!(params.iter().any(|&x| x != 0.0));
        // Denoiser signature sanity.
        let d = m.denoiser(8).unwrap();
        assert_eq!(d.inputs[1].shape, vec![8, 32, 4]);
        assert_eq!(d.outputs[0].shape, vec![8, 32, 4]);
    }

    #[test]
    fn missing_dir_is_friendly_error() {
        let err = Manifest::load("/nonexistent-dir").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn tokens_for_rows_math() {
        let m = ModelInfo {
            latent_h: 32, latent_w: 32, latent_c: 4, patch: 2, dim: 96,
            heads: 4, layers: 3, temb_dim: 64, row_granularity: 4,
            tokens_full: 256, param_count: 1, params_seed: 0,
        };
        assert_eq!(m.tokens_for_rows(8), 64);
        assert_eq!(m.tokens_for_rows(32), 256);
        assert_eq!(m.kv_shape(), vec![3, 256, 192]);
        // Re-basing keeps everything but the latent geometry.
        let half = m.with_resolution(16, 32);
        assert_eq!(half.latent_h, 16);
        assert_eq!(half.tokens_full, 128);
        assert_eq!(half.kv_shape(), vec![3, 128, 192]);
        assert_eq!(half.dim, m.dim);
        assert_eq!(half.row_granularity, m.row_granularity);
    }

    fn stub_dir(tag: &str, extra: &[(usize, usize)]) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "stadi-artifacts-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        crate::runtime::stubgen::write_stub_artifacts(&dir, extra).unwrap();
        dir
    }

    #[test]
    fn registry_loads_lazily_and_bounds_residency_lru() {
        let dir =
            stub_dir("lru", &[(16, 32), (48, 32), (8, 32)]);
        let reg = ArtifactRegistry::with_capacity(&dir, 2).unwrap();
        // Nothing resident until first use; native is always free.
        assert_eq!(reg.stats(), RegistryStats::default());
        reg.get(reg.native_key()).unwrap();
        assert_eq!(reg.stats().resident, 0);
        let (a, b, c) = (
            ResKey { h: 16, w: 32 },
            ResKey { h: 48, w: 32 },
            ResKey { h: 8, w: 32 },
        );
        reg.get(a).unwrap();
        reg.get(b).unwrap();
        assert_eq!(
            reg.stats(),
            RegistryStats { resident: 2, loads: 2, evictions: 0 }
        );
        // Touch `a` so `b` becomes least-recently-used, then load a
        // third: `b` is evicted, the cap holds.
        reg.get(a).unwrap();
        reg.get(c).unwrap();
        let s = reg.stats();
        assert_eq!((s.resident, s.loads, s.evictions), (2, 3, 1));
        // The evicted resolution reloads transparently on demand.
        reg.get(b).unwrap();
        assert_eq!(reg.stats().loads, 4);
        // Unregistered sizes are a typed error naming the options.
        let e = reg.get(ResKey { h: 20, w: 32 }).unwrap_err();
        assert!(e.to_string().contains("not registered"), "{e}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resolution_file_problems_surface_on_first_get_not_at_load() {
        let dir = stub_dir("lazyerr", &[(16, 32)]);
        std::fs::remove_file(dir.join("denoiser_16x32_h4.hlo")).unwrap();
        // Registry load succeeds — validation of non-native sets is
        // deferred (a server should boot even if a cold size is
        // broken)...
        let reg = ArtifactRegistry::load(&dir).unwrap();
        assert!(reg.is_registered(ResKey { h: 16, w: 32 }));
        // ...and the first get reports the missing file.
        let e = reg.get(ResKey { h: 16, w: 32 }).unwrap_err();
        assert!(e.to_string().contains("missing"), "{e}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
