//! Artifact manifest: the ABI between `python/compile/aot.py` and this
//! runtime. Parses `artifacts/manifest.json`, validates file presence
//! and sizes, and loads `params.bin`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::{self, Value};

/// Model geometry as recorded by the AOT step (mirror of
/// `python/compile/config.py::ModelConfig`).
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub latent_h: usize,
    pub latent_w: usize,
    pub latent_c: usize,
    pub patch: usize,
    pub dim: usize,
    pub heads: usize,
    pub layers: usize,
    pub temb_dim: usize,
    pub row_granularity: usize,
    pub tokens_full: usize,
    pub param_count: usize,
    pub params_seed: u64,
}

impl ModelInfo {
    pub fn tokens_for_rows(&self, rows: usize) -> usize {
        assert_eq!(rows % self.patch, 0);
        (rows / self.patch) * (self.latent_w / self.patch)
    }

    /// Shape of one latent image.
    pub fn latent_shape(&self) -> Vec<usize> {
        vec![self.latent_h, self.latent_w, self.latent_c]
    }

    /// Shape of the full per-layer KV buffer stack [L, T_full, 2D].
    pub fn kv_shape(&self) -> Vec<usize> {
        vec![self.layers, self.tokens_full, 2 * self.dim]
    }
}

/// Schedule parameters recorded by AOT (mirror of ScheduleConfig).
#[derive(Debug, Clone)]
pub struct ScheduleInfo {
    pub train_steps: usize,
    pub beta_start: f64,
    pub beta_end: f64,
}

/// One input/output slot of an artifact.
#[derive(Debug, Clone)]
pub struct Slot {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One AOT'd HLO-text artifact.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub key: String,
    pub file: PathBuf,
    pub inputs: Vec<Slot>,
    pub outputs: Vec<Slot>,
    pub bytes: usize,
}

/// Parsed manifest + resolved paths.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelInfo,
    pub schedule: ScheduleInfo,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
    /// Patch heights with a denoiser artifact, ascending.
    pub patch_heights: Vec<usize>,
}

fn parse_slots(v: &Value) -> Result<Vec<Slot>> {
    v.as_arr()?
        .iter()
        .map(|s| {
            Ok(Slot {
                name: s.get("name")?.as_str()?.to_string(),
                shape: s.get("shape")?.usizes()?,
                dtype: s.get("dtype")?.as_str()?.to_string(),
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        if !path.exists() {
            return Err(Error::Artifact(format!(
                "{} not found — run `make artifacts` first",
                path.display()
            )));
        }
        let v = json::from_file(&path)?;

        let m = v.get("model")?;
        let model = ModelInfo {
            latent_h: m.get("latent_h")?.as_usize()?,
            latent_w: m.get("latent_w")?.as_usize()?,
            latent_c: m.get("latent_c")?.as_usize()?,
            patch: m.get("patch")?.as_usize()?,
            dim: m.get("dim")?.as_usize()?,
            heads: m.get("heads")?.as_usize()?,
            layers: m.get("layers")?.as_usize()?,
            temb_dim: m.get("temb_dim")?.as_usize()?,
            row_granularity: m.get("row_granularity")?.as_usize()?,
            tokens_full: m.get("tokens_full")?.as_usize()?,
            param_count: m.get("param_count")?.as_usize()?,
            params_seed: m.get("params_seed")?.as_i64()? as u64,
        };
        let s = v.get("schedule")?;
        let schedule = ScheduleInfo {
            train_steps: s.get("train_steps")?.as_usize()?,
            beta_start: s.get("beta_start")?.as_f64()?,
            beta_end: s.get("beta_end")?.as_f64()?,
        };

        let mut artifacts = BTreeMap::new();
        let mut patch_heights = Vec::new();
        for (key, a) in v.get("artifacts")?.as_obj()?.iter() {
            let file = dir.join(a.get("file")?.as_str()?);
            let bytes = a.get("bytes")?.as_usize()?;
            if !file.exists() {
                return Err(Error::Artifact(format!(
                    "artifact file missing: {}",
                    file.display()
                )));
            }
            let actual = std::fs::metadata(&file)?.len() as usize;
            if actual != bytes {
                return Err(Error::Artifact(format!(
                    "{}: size {actual} != manifest {bytes} (stale \
                     artifacts? re-run `make artifacts`)",
                    file.display()
                )));
            }
            if let Some(hs) = key.strip_prefix("denoiser_h") {
                patch_heights.push(hs.parse::<usize>().map_err(|_| {
                    Error::Artifact(format!("bad artifact key {key}"))
                })?);
            }
            artifacts.insert(
                key.clone(),
                ArtifactInfo {
                    key: key.clone(),
                    file,
                    inputs: parse_slots(a.get("inputs")?)?,
                    outputs: parse_slots(a.get("outputs")?)?,
                    bytes,
                },
            );
        }
        patch_heights.sort_unstable();
        if patch_heights.is_empty() {
            return Err(Error::Artifact("no denoiser artifacts".into()));
        }

        Ok(Manifest { dir, model, schedule, artifacts, patch_heights })
    }

    pub fn artifact(&self, key: &str) -> Result<&ArtifactInfo> {
        self.artifacts
            .get(key)
            .ok_or_else(|| Error::Artifact(format!("no artifact {key:?}")))
    }

    pub fn denoiser(&self, h: usize) -> Result<&ArtifactInfo> {
        self.artifact(&format!("denoiser_h{h}"))
    }

    /// Load the flat f32 weight vector, validating its length.
    pub fn load_params(&self) -> Result<Vec<f32>> {
        let path = self.dir.join("params.bin");
        let bytes = std::fs::read(&path)?;
        if bytes.len() != self.model.param_count * 4 {
            return Err(Error::Artifact(format!(
                "params.bin: {} bytes, expected {}",
                bytes.len(),
                self.model.param_count * 4
            )));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Read a golden JSON file dumped by aot.py.
    pub fn golden(&self, name: &str) -> Result<Value> {
        json::from_file(&self.dir.join("golden").join(name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        // Tests run from the crate root.
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_manifest_and_params() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(artifacts_dir()).unwrap();
        assert_eq!(m.model.latent_h, 32);
        assert!(m.patch_heights.contains(&32));
        assert!(m.patch_heights.contains(&8));
        let params = m.load_params().unwrap();
        assert_eq!(params.len(), m.model.param_count);
        // Non-degenerate weights.
        assert!(params.iter().any(|&x| x != 0.0));
        // Denoiser signature sanity.
        let d = m.denoiser(8).unwrap();
        assert_eq!(d.inputs[1].shape, vec![8, 32, 4]);
        assert_eq!(d.outputs[0].shape, vec![8, 32, 4]);
    }

    #[test]
    fn missing_dir_is_friendly_error() {
        let err = Manifest::load("/nonexistent-dir").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn tokens_for_rows_math() {
        let m = ModelInfo {
            latent_h: 32, latent_w: 32, latent_c: 4, patch: 2, dim: 96,
            heads: 4, layers: 3, temb_dim: 64, row_granularity: 4,
            tokens_full: 256, param_count: 1, params_seed: 0,
        };
        assert_eq!(m.tokens_for_rows(8), 64);
        assert_eq!(m.tokens_for_rows(32), 256);
        assert_eq!(m.kv_shape(), vec![3, 256, 192]);
    }
}
