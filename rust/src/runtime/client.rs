//! PJRT execution: load HLO-text artifacts, compile once, execute many.
//!
//! Wraps the `xla` crate (PjRtClient -> HloModuleProto::from_text_file
//! -> compile -> execute). One `Runtime` is shared by all simulated
//! devices — the physical CPU is the single execution substrate and
//! heterogeneity is imposed by the device layer (DESIGN.md §3), so a
//! shared executable cache both matches reality (one binary per model
//! variant) and avoids recompiling per device.
//!
//! The real PJRT path lives behind the `xla-backend` feature; the
//! default build substitutes a stub whose constructor fails with a
//! clear message, so the rest of the stack (planner, router, server,
//! DES, benches' simulated paths) builds and tests on a bare
//! toolchain with no registry access.

use crate::runtime::tensor::Tensor;

/// Typed inputs for one denoiser step.
#[derive(Debug, Clone)]
pub struct DenoiserInputs<'a> {
    /// Flat weights (shared, fed by reference each call).
    pub params: &'a [f32],
    /// This device's latent rows [h, W, C].
    pub x_patch: &'a Tensor,
    /// Full stale KV stack [L, T_full, 2D].
    pub kv_stale: &'a Tensor,
    /// First latent row of the patch.
    pub row_off: usize,
    /// Diffusion timestep index (as trained, 0..train_steps).
    pub t: f64,
    /// Conditioning vector [D].
    pub cond: &'a [f32],
}

/// Outputs of one denoiser step.
#[derive(Debug, Clone)]
pub struct DenoiserOutputs {
    /// Predicted noise for the patch [h, W, C].
    pub eps_patch: Tensor,
    /// Fresh own-token KV per layer [L, T_own, 2D].
    pub kv_fresh: Tensor,
}

/// Error text for builds without the `xla-backend` feature. Referenced
/// by the stub runtime below and by `ExecService::spawn` (which checks
/// the feature *before* the artifacts directory, so a stub build
/// reports the actual problem instead of "artifacts not found").
pub(crate) const NO_BACKEND: &str = "stadi was built without the \
     `xla-backend` feature; real PJRT execution is unavailable. To \
     enable it, point the `xla` dependency in rust/Cargo.toml at the \
     real xla-rs crate (the default is the offline API stub in \
     rust/xla-stub), then rebuild with `cargo build --features \
     xla-backend`";

pub use backend::Runtime;

#[cfg(feature = "xla-backend")]
mod backend {
    use std::collections::BTreeMap;
    use std::sync::{Arc, Mutex};

    use crate::error::{Error, Result};
    use crate::runtime::artifacts::{
        ArtifactInfo, ArtifactRegistry, Manifest, ResKey,
    };
    use crate::runtime::tensor::Tensor;

    use super::{DenoiserInputs, DenoiserOutputs};

    /// A compiled artifact ready to execute.
    struct Compiled {
        exe: xla::PjRtLoadedExecutable,
        /// Retained for diagnostics (artifact identity in error paths).
        #[allow(dead_code)]
        info: ArtifactInfo,
    }

    /// PJRT CPU runtime with a compiled-executable cache, keyed by
    /// artifact key (unique across the registry's resolutions).
    ///
    /// Execution goes through `execute_b` with explicitly-managed device
    /// buffers: the literal-taking `execute` of xla 0.1.6 leaks the
    /// transient input device buffers it creates internally (~3 MB per
    /// denoiser step — enough to OOM a quality sweep), while
    /// `PjRtBuffer`'s Drop frees properly. This also lets us upload the
    /// 2.2 MB weight vector once and reuse the device buffer across every
    /// step (see `params_buffer`).
    pub struct Runtime {
        client: xla::PjRtClient,
        registry: Arc<ArtifactRegistry>,
        cache: Mutex<BTreeMap<String, std::sync::Arc<Compiled>>>,
        /// Which *non-native* resolution each compiled key belongs to:
        /// when the registry evicts a resolution, `track_and_prune`
        /// drops its compiled executables too, so the registry's LRU
        /// cap bounds the heavyweight objects and not just the
        /// metadata.
        owners: Mutex<BTreeMap<String, ResKey>>,
        /// Registry eviction count last reconciled against `owners` —
        /// the full prune scan only runs when it advances, so
        /// steady-state denoise steps pay one atomic compare, not a
        /// map walk under two locks.
        pruned_at: std::sync::atomic::AtomicU64,
        /// Cached device buffer for the flat weights, keyed by the host
        /// pointer + length of the slice it was uploaded from (the exec
        /// service owns one stable params vec for the process lifetime).
        params_buffer: Mutex<Option<(usize, usize, xla::PjRtBuffer)>>,
    }

    impl Runtime {
        pub fn new(registry: Arc<ArtifactRegistry>) -> Result<Self> {
            let client = xla::PjRtClient::cpu()?;
            Ok(Runtime {
                client,
                registry,
                cache: Mutex::new(BTreeMap::new()),
                owners: Mutex::new(BTreeMap::new()),
                pruned_at: std::sync::atomic::AtomicU64::new(0),
                params_buffer: Mutex::new(None),
            })
        }

        /// Record a compiled key's owning resolution and — only when
        /// the registry has evicted something since the last check —
        /// drop compiled executables whose resolution is no longer
        /// resident. Lock order: owners, then cache (only this path
        /// takes both).
        fn track_and_prune(&self, res: ResKey, key: &str) {
            use std::sync::atomic::Ordering;
            if res == self.registry.native_key() {
                return;
            }
            let mut owners = self.owners.lock().unwrap();
            owners.insert(key.to_string(), res);
            let evictions = self.registry.stats().evictions;
            if self.pruned_at.swap(evictions, Ordering::Relaxed)
                == evictions
            {
                return;
            }
            let mut cache = self.cache.lock().unwrap();
            owners.retain(|k, &mut owner| {
                if self.registry.is_resident(owner) {
                    true
                } else {
                    cache.remove(k);
                    false
                }
            });
        }

        /// Host-to-device upload with proper ownership (freed on drop).
        fn upload(
            &self,
            data: &[f32],
            dims: &[usize],
        ) -> Result<xla::PjRtBuffer> {
            Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
        }

        fn upload_scalar_f32(&self, v: f32) -> Result<xla::PjRtBuffer> {
            Ok(self.client.buffer_from_host_buffer(&[v], &[], None)?)
        }

        fn upload_scalar_i32(&self, v: i32) -> Result<xla::PjRtBuffer> {
            Ok(self.client.buffer_from_host_buffer(&[v], &[], None)?)
        }

        pub fn manifest(&self) -> &Manifest {
            self.registry.manifest()
        }

        pub fn registry(&self) -> &Arc<ArtifactRegistry> {
            &self.registry
        }

        /// Compile (or fetch cached) an artifact.
        fn compiled(&self, info: &ArtifactInfo) -> Result<std::sync::Arc<Compiled>> {
            let key = &info.key;
            if let Some(c) = self.cache.lock().unwrap().get(key) {
                return Ok(c.clone());
            }
            crate::log_debug!("runtime", "compiling artifact {key}");
            let proto = xla::HloModuleProto::from_text_file(
                info.file.to_str().ok_or_else(|| Error::msg("bad path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            let arc =
                std::sync::Arc::new(Compiled { exe, info: info.clone() });
            self.cache.lock().unwrap().insert(key.clone(), arc.clone());
            Ok(arc)
        }

        /// Pre-compile a resolution's denoisers at the given patch
        /// heights (leader does this before serving so compilation
        /// never lands on the request path).
        pub fn warm_at(&self, res: ResKey, heights: &[usize]) -> Result<()> {
            let ra = self.registry.get(res)?;
            for &h in heights {
                let info = ra.denoiser(h)?;
                self.compiled(info)?;
                self.track_and_prune(res, &info.key);
            }
            Ok(())
        }

        /// Number of artifacts currently compiled.
        pub fn cache_len(&self) -> usize {
            self.cache.lock().unwrap().len()
        }

        /// Execute a native-resolution denoiser step (the legacy
        /// single-resolution entry point).
        pub fn denoise(
            &self,
            h: usize,
            inp: &DenoiserInputs<'_>,
        ) -> Result<DenoiserOutputs> {
            self.denoise_at(self.registry.native_key(), h, inp)
        }

        /// Execute a denoiser artifact for patch height `h` at a
        /// registered resolution.
        pub fn denoise_at(
            &self,
            res: ResKey,
            h: usize,
            inp: &DenoiserInputs<'_>,
        ) -> Result<DenoiserOutputs> {
            let ra = self.registry.get(res)?;
            let info = ra.denoiser(h)?;
            let c = self.compiled(info)?;
            self.track_and_prune(res, &info.key);
            let m = &ra.model;
            // Shape checks against the manifest ABI.
            if inp.x_patch.shape != vec![h, m.latent_w, m.latent_c] {
                return Err(Error::Artifact(format!(
                    "x_patch shape {:?} != [{h}, {}, {}]",
                    inp.x_patch.shape, m.latent_w, m.latent_c
                )));
            }
            if inp.kv_stale.shape != m.kv_shape() {
                return Err(Error::Artifact(format!(
                    "kv_stale shape {:?} != {:?}",
                    inp.kv_stale.shape,
                    m.kv_shape()
                )));
            }
            if inp.params.len() != m.param_count || inp.cond.len() != m.dim {
                return Err(Error::Artifact(
                    "params/cond length mismatch".into(),
                ));
            }
            if inp.row_off % m.patch != 0 || inp.row_off + h > m.latent_h {
                return Err(Error::Artifact(format!(
                    "bad row_off {} for h {h}",
                    inp.row_off
                )));
            }

            // Weights upload amortized across calls (same host slice).
            let key = (inp.params.as_ptr() as usize, inp.params.len());
            {
                let mut pb = self.params_buffer.lock().unwrap();
                let stale = match &*pb {
                    Some((p, l, _)) => (*p, *l) != key,
                    None => true,
                };
                if stale {
                    *pb = Some((
                        key.0,
                        key.1,
                        self.upload(inp.params, &[inp.params.len()])?,
                    ));
                }
            }
            let x_buf = self.upload(&inp.x_patch.data, &inp.x_patch.shape)?;
            let kv_buf =
                self.upload(&inp.kv_stale.data, &inp.kv_stale.shape)?;
            let ro_buf = self.upload_scalar_i32(inp.row_off as i32)?;
            let t_buf = self.upload_scalar_f32(inp.t as f32)?;
            let cond_buf = self.upload(inp.cond, &[inp.cond.len()])?;

            let pb = self.params_buffer.lock().unwrap();
            let params_buf = &pb.as_ref().unwrap().2;
            let result = c
                .exe
                .execute_b::<&xla::PjRtBuffer>(&[
                    params_buf, &x_buf, &kv_buf, &ro_buf, &t_buf, &cond_buf,
                ])?[0][0]
                .to_literal_sync()?;
            drop(pb);
            let (eps_lit, kv_lit) = result.to_tuple2()?;

            let t_own = m.tokens_for_rows(h);
            Ok(DenoiserOutputs {
                eps_patch: Tensor::from_literal(
                    &eps_lit,
                    vec![h, m.latent_w, m.latent_c],
                )?,
                kv_fresh: Tensor::from_literal(
                    &kv_lit,
                    vec![m.layers, t_own, 2 * m.dim],
                )?,
            })
        }

        /// Execute the AOT'd DDIM update artifact (full latent).
        /// The hot path uses the rust-native `model::sampler` instead; this
        /// exists to cross-validate the two (see tests/integration).
        pub fn ddim_update(
            &self,
            x: &Tensor,
            eps: &Tensor,
            coef_x: f64,
            coef_eps: f64,
        ) -> Result<Tensor> {
            let c = self.compiled(self.manifest().artifact("ddim_update")?)?;
            let bufs = [
                self.upload(&x.data, &x.shape)?,
                self.upload(&eps.data, &eps.shape)?,
                self.upload_scalar_f32(coef_x as f32)?,
                self.upload_scalar_f32(coef_eps as f32)?,
            ];
            let result = c
                .exe
                .execute_b::<&xla::PjRtBuffer>(&[
                    &bufs[0], &bufs[1], &bufs[2], &bufs[3],
                ])?[0][0]
                .to_literal_sync()?;
            let out = result.to_tuple1()?;
            Tensor::from_literal(&out, x.shape.clone())
        }

        /// Run the feature extractor (LPIPS/FID proxy).
        /// Returns the per-stage pooled features (f1, f2, f3).
        pub fn features(
            &self,
            x: &Tensor,
        ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
            let c = self.compiled(self.manifest().artifact("features")?)?;
            let x_buf = self.upload(&x.data, &x.shape)?;
            let result = c
                .exe
                .execute_b::<&xla::PjRtBuffer>(&[&x_buf])?[0][0]
                .to_literal_sync()?;
            let (f1, f2, f3) = result.to_tuple3()?;
            Ok((
                f1.to_vec::<f32>()?,
                f2.to_vec::<f32>()?,
                f3.to_vec::<f32>()?,
            ))
        }
    }
}

#[cfg(not(feature = "xla-backend"))]
mod backend {
    //! Stub runtime for builds without the `xla-backend` feature.
    //!
    //! `Runtime::new` fails immediately (so `ExecService::spawn`
    //! reports a clear error instead of failing on the first denoise),
    //! and every execution method exists only to keep the callers
    //! type-checking.

    use std::sync::Arc;

    use crate::error::{Error, Result};
    use crate::runtime::artifacts::{ArtifactRegistry, Manifest, ResKey};
    use crate::runtime::tensor::Tensor;

    use super::{DenoiserInputs, DenoiserOutputs, NO_BACKEND};

    /// Placeholder with the same API surface as the real PJRT runtime.
    pub struct Runtime {
        registry: Arc<ArtifactRegistry>,
    }

    impl Runtime {
        pub fn new(_registry: Arc<ArtifactRegistry>) -> Result<Self> {
            // Fail early: constructing a runtime that cannot execute
            // anything would only defer this error to the request path.
            Err(Error::msg(NO_BACKEND))
        }

        pub fn manifest(&self) -> &Manifest {
            self.registry.manifest()
        }

        pub fn registry(&self) -> &Arc<ArtifactRegistry> {
            &self.registry
        }

        pub fn warm_at(
            &self,
            _res: ResKey,
            _heights: &[usize],
        ) -> Result<()> {
            Err(Error::msg(NO_BACKEND))
        }

        pub fn cache_len(&self) -> usize {
            0
        }

        pub fn denoise(
            &self,
            _h: usize,
            _inp: &DenoiserInputs<'_>,
        ) -> Result<DenoiserOutputs> {
            Err(Error::msg(NO_BACKEND))
        }

        pub fn denoise_at(
            &self,
            _res: ResKey,
            _h: usize,
            _inp: &DenoiserInputs<'_>,
        ) -> Result<DenoiserOutputs> {
            Err(Error::msg(NO_BACKEND))
        }

        pub fn ddim_update(
            &self,
            _x: &Tensor,
            _eps: &Tensor,
            _coef_x: f64,
            _coef_eps: f64,
        ) -> Result<Tensor> {
            Err(Error::msg(NO_BACKEND))
        }

        pub fn features(
            &self,
            _x: &Tensor,
        ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
            Err(Error::msg(NO_BACKEND))
        }
    }
}

#[cfg(all(test, feature = "xla-backend"))]
mod tests {
    use super::*;
    use crate::runtime::artifacts::ArtifactRegistry;
    use crate::util::rng::NormalGen;
    use std::path::PathBuf;
    use std::sync::Arc;

    fn registry() -> Option<Arc<ArtifactRegistry>> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            Some(Arc::new(ArtifactRegistry::load(dir).unwrap()))
        } else {
            eprintln!("skipping: artifacts not built");
            None
        }
    }

    #[test]
    fn denoiser_matches_golden() {
        let Some(reg) = registry() else { return };
        // Inputs regenerated through the cross-language PCG stream
        // (compile/pcg.py == util::rng), draw order: x, kv, cond —
        // exactly how aot.py::golden_denoiser produced them.
        let golden = reg.manifest().golden("denoiser.json").unwrap();
        let rt = Runtime::new(reg).unwrap();
        let model = rt.manifest().model.clone();
        let params = rt.manifest().load_params().unwrap();

        let h = golden.get("h").unwrap().as_usize().unwrap();
        let seed = golden.get("seed").unwrap().as_i64().unwrap() as u64;
        let mut gen = NormalGen::new(seed);
        let x = Tensor::new(
            vec![h, model.latent_w, model.latent_c],
            gen.vec_f32(h * model.latent_w * model.latent_c),
        )
        .unwrap();
        let kv = Tensor::new(
            model.kv_shape(),
            gen.vec_f32(model.kv_shape().iter().product()),
        )
        .unwrap();
        let cond = gen.vec_f32(model.dim);
        let inp = DenoiserInputs {
            params: &params,
            x_patch: &x,
            kv_stale: &kv,
            row_off: golden.get("row_off").unwrap().as_usize().unwrap(),
            t: golden.get("t").unwrap().as_f64().unwrap(),
            cond: &cond,
        };
        let out1 = rt.denoise(h, &inp).unwrap();
        let out2 = rt.denoise(h, &inp).unwrap();
        assert_eq!(out1.eps_patch, out2.eps_patch, "non-deterministic");
        assert_eq!(out1.kv_fresh.shape, vec![3, 64, 192]);
        assert_eq!(rt.cache_len(), 1);

        // Python-vs-rust equality on the recorded values.
        let want_first16 = golden.get("eps_first16").unwrap().f32s().unwrap();
        for (i, w) in want_first16.iter().enumerate() {
            assert!(
                (out1.eps_patch.data[i] - w).abs() < 1e-4,
                "eps[{i}]: {} vs {w}",
                out1.eps_patch.data[i]
            );
        }
        let want_sum = golden.get("eps_sum").unwrap().as_f64().unwrap();
        assert!(
            (out1.eps_patch.sum() - want_sum).abs()
                < 1e-3 * want_sum.abs().max(1.0),
            "eps sum {} vs {want_sum}",
            out1.eps_patch.sum()
        );
        let want_kv16 = golden.get("kv_first16").unwrap().f32s().unwrap();
        for (i, w) in want_kv16.iter().enumerate() {
            assert!((out1.kv_fresh.data[i] - w).abs() < 1e-4);
        }
    }

    #[test]
    fn ddim_artifact_is_fma() {
        let Some(reg) = registry() else { return };
        let rt = Runtime::new(reg).unwrap();
        let shape = rt.manifest().model.latent_shape();
        let mut gen = NormalGen::new(2);
        let n: usize = shape.iter().product();
        let x = Tensor::new(shape.clone(), gen.vec_f32(n)).unwrap();
        let eps = Tensor::new(shape.clone(), gen.vec_f32(n)).unwrap();
        let out = rt.ddim_update(&x, &eps, 0.5, -0.25).unwrap();
        for i in 0..n {
            let want = 0.5 * x.data[i] - 0.25 * eps.data[i];
            assert!((out.data[i] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn features_shapes() {
        let Some(reg) = registry() else { return };
        let rt = Runtime::new(reg).unwrap();
        let shape = rt.manifest().model.latent_shape();
        let n: usize = shape.iter().product();
        let x = Tensor::new(shape, NormalGen::new(3).vec_f32(n)).unwrap();
        let (f1, f2, f3) = rt.features(&x).unwrap();
        assert_eq!((f1.len(), f2.len(), f3.len()), (16, 32, 64));
    }

    #[test]
    fn rejects_bad_shapes() {
        let Some(reg) = registry() else { return };
        let rt = Runtime::new(reg).unwrap();
        let params = rt.manifest().load_params().unwrap();
        let model = rt.manifest().model.clone();
        let x = Tensor::zeros(&[8, 32, 4]);
        let kv = Tensor::zeros(&[3, 256, 192]);
        let cond = vec![0.0f32; model.dim];
        // row_off not a multiple of patch
        let inp = DenoiserInputs {
            params: &params, x_patch: &x, kv_stale: &kv,
            row_off: 3, t: 0.0, cond: &cond,
        };
        assert!(rt.denoise(8, &inp).is_err());
        // patch overruns the latent
        let inp = DenoiserInputs {
            params: &params, x_patch: &x, kv_stale: &kv,
            row_off: 28, t: 0.0, cond: &cond,
        };
        assert!(rt.denoise(8, &inp).is_err());
    }
}
