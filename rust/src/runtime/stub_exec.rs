//! Deterministic stub execution backend.
//!
//! Executes "stub" artifact sets (see [`crate::runtime::stubgen`])
//! with cheap, fully deterministic arithmetic in place of PJRT: the
//! epsilon prediction is a seeded contraction of the input patch, so
//! latents depend on the request seed and the plan's patch split
//! exactly like the real path (split-dependent outputs, Table II),
//! while byte-identical inputs always produce byte-identical outputs —
//! which is what lets integration tests pin latent sums offline.
//!
//! The backend enforces the same ABI as the real runtime: shape checks
//! against the resolution's model geometry, and a denoiser artifact
//! must exist for the requested patch height (a missing height fails
//! here just like a missing HLO file fails compilation).

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::runtime::artifacts::{ArtifactRegistry, Manifest, ResKey};
use crate::runtime::client::{DenoiserInputs, DenoiserOutputs};
use crate::runtime::tensor::Tensor;
use crate::util::rng::NormalGen;

/// Stub runtime over a resolution-keyed registry.
pub struct StubExec {
    registry: Arc<ArtifactRegistry>,
}

/// Mix the call's identifying fields into one PRNG stream seed. Two
/// calls agree on their noise stream iff they agree on resolution,
/// patch geometry and timestep — the inputs the real compiled kernel
/// would see.
fn stream_seed(
    params_seed: u64,
    res: ResKey,
    h: usize,
    row_off: usize,
    t: f64,
) -> u64 {
    let mut s = params_seed ^ 0x5851_f42d_4c95_7f2d;
    for v in [
        res.h as u64,
        res.w as u64,
        h as u64,
        row_off as u64,
        t.to_bits(),
    ] {
        s = s
            .rotate_left(13)
            .wrapping_add(v.wrapping_mul(0x9e37_79b9_7f4a_7c15));
    }
    s
}

impl StubExec {
    pub fn new(registry: Arc<ArtifactRegistry>) -> Result<Self> {
        if !registry.manifest().stub {
            return Err(Error::Artifact(
                "refusing stub execution of non-stub artifacts (the \
                 manifest lacks \"stub\": true)"
                    .into(),
            ));
        }
        Ok(StubExec { registry })
    }

    pub fn manifest(&self) -> &Manifest {
        self.registry.manifest()
    }

    pub fn registry(&self) -> &Arc<ArtifactRegistry> {
        &self.registry
    }

    /// The deterministic occupancy drift schedule this stub set ships
    /// (manifest `"drift"` table), if any. The stub backend itself
    /// never sleeps on it — drift shapes the engine's *virtual* clocks
    /// (in-request drift detection + timeline), which is what keeps
    /// injected-drift scenarios byte-reproducible on any build.
    pub fn drift(&self) -> Option<&crate::device::OccupancySchedule> {
        self.manifest().drift.as_ref()
    }

    /// One deterministic denoiser step at resolution `res`.
    pub fn denoise(
        &self,
        res: ResKey,
        h: usize,
        inp: &DenoiserInputs<'_>,
    ) -> Result<DenoiserOutputs> {
        let ra = self.registry.get(res)?;
        let m = &ra.model;
        // The patch height must be AOT'd, like the real compile path.
        ra.denoiser_key(h)?;
        // Same ABI checks as the PJRT backend.
        if inp.x_patch.shape != vec![h, m.latent_w, m.latent_c] {
            return Err(Error::Artifact(format!(
                "x_patch shape {:?} != [{h}, {}, {}]",
                inp.x_patch.shape, m.latent_w, m.latent_c
            )));
        }
        if inp.kv_stale.shape != m.kv_shape() {
            return Err(Error::Artifact(format!(
                "kv_stale shape {:?} != {:?}",
                inp.kv_stale.shape,
                m.kv_shape()
            )));
        }
        if inp.params.len() != m.param_count || inp.cond.len() != m.dim {
            return Err(Error::Artifact(
                "params/cond length mismatch".into(),
            ));
        }
        if inp.row_off % m.patch != 0 || inp.row_off + h > m.latent_h {
            return Err(Error::Artifact(format!(
                "bad row_off {} for h {h}",
                inp.row_off
            )));
        }

        let mut gen = NormalGen::new(stream_seed(
            m.params_seed,
            res,
            h,
            inp.row_off,
            inp.t,
        ));
        // Optional KV coupling (manifest "kv_gain"): fold the stale KV
        // stack's per-column means into every eps sample, so the
        // output depends on *neighbor-published* context and displaced
        // halo staleness becomes measurable. Gated on > 0 so absent /
        // zero gains keep the legacy arithmetic byte for byte (even
        // `v + 0.0` can flip a -0.0 sign bit).
        let kv_ctx: Option<(f32, Vec<f32>)> = match self
            .manifest()
            .kv_gain
        {
            Some(g) if g > 0.0 => {
                let cols = 2 * m.dim;
                let toks = m.tokens_full;
                let mut mean = vec![0.0f32; cols];
                for t in 0..toks {
                    for (c, acc) in mean.iter_mut().enumerate() {
                        *acc += inp.kv_stale.data[t * cols + c];
                    }
                }
                let inv = 1.0 / toks as f32;
                for v in &mut mean {
                    *v *= inv;
                }
                Some((g as f32, mean))
            }
            _ => None,
        };
        let n = h * m.latent_w * m.latent_c;
        let z = gen.vec_f32(n);
        let mut eps = Vec::with_capacity(n);
        for i in 0..n {
            // A contraction of the noisy patch plus step/condition
            // noise: DDIM trajectories stay bounded and every input
            // byte influences the output deterministically.
            let mut v = 0.7 * inp.x_patch.data[i]
                + 0.2 * z[i]
                + 0.1 * inp.cond[i % m.dim];
            if let Some((g, ctx)) = &kv_ctx {
                v += g * ctx[i % ctx.len()];
            }
            eps.push(v.clamp(-4.0, 4.0));
        }
        let t_own = m.tokens_for_rows(h);
        let kv: Vec<f32> = gen
            .vec_f32(m.layers * t_own * 2 * m.dim)
            .into_iter()
            .map(|v| 0.01 * v)
            .collect();
        Ok(DenoiserOutputs {
            eps_patch: Tensor::new(vec![h, m.latent_w, m.latent_c], eps)?,
            kv_fresh: Tensor::new(vec![m.layers, t_own, 2 * m.dim], kv)?,
        })
    }

    /// The DDIM-update artifact is a pure FMA; the stub computes it
    /// exactly, so cross-validation against the rust-native sampler
    /// holds on stub builds too.
    pub fn ddim_update(
        &self,
        x: &Tensor,
        eps: &Tensor,
        coef_x: f64,
        coef_eps: f64,
    ) -> Result<Tensor> {
        if x.shape != eps.shape {
            return Err(Error::Artifact(format!(
                "ddim_update shape mismatch: {:?} vs {:?}",
                x.shape, eps.shape
            )));
        }
        let data: Vec<f32> = x
            .data
            .iter()
            .zip(&eps.data)
            .map(|(&xv, &ev)| (coef_x * xv as f64 + coef_eps * ev as f64) as f32)
            .collect();
        Tensor::new(x.shape.clone(), data)
    }

    /// Deterministic pooled pseudo-features (16/32/64 wide, like the
    /// real extractor): chunked means of the input, so metric smoke
    /// tests get stable, input-dependent values.
    pub fn features(
        &self,
        x: &Tensor,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let pool = |width: usize| -> Vec<f32> {
            let n = x.data.len();
            (0..width)
                .map(|k| {
                    if n == 0 {
                        return 0.0;
                    }
                    let lo = (k * n / width).min(n - 1);
                    let hi = ((k + 1) * n / width).clamp(lo + 1, n);
                    let s: f32 = x.data[lo..hi].iter().sum();
                    s / (hi - lo) as f32
                })
                .collect()
        };
        Ok((pool(16), pool(32), pool(64)))
    }

    /// Warm = validate the artifacts exist (there is nothing to
    /// compile), mirroring the real path's failure mode.
    pub fn warm(&self, res: ResKey, heights: &[usize]) -> Result<()> {
        let ra = self.registry.get(res)?;
        for &h in heights {
            ra.denoiser_key(h)?;
        }
        Ok(())
    }

    /// Calibrate the affine cost model by timing stub steps — the
    /// timings are real wall-clock measurements of the stub
    /// arithmetic, tiny but positive and monotone in rows.
    pub fn calibrate(&self, reps: usize) -> Result<crate::device::CostModel> {
        let native = self.registry.native_key();
        crate::device::CostModel::calibrate_with(
            self.manifest(),
            reps,
            |h, inp| self.denoise(native, h, inp),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::stubgen;

    fn registry(tag: &str) -> (std::path::PathBuf, Arc<ArtifactRegistry>) {
        let dir = std::env::temp_dir()
            .join(format!("stadi-stubexec-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        stubgen::write_stub_artifacts(
            &dir,
            stubgen::DEFAULT_EXTRA_RESOLUTIONS,
        )
        .unwrap();
        (dir.clone(), Arc::new(ArtifactRegistry::load(&dir).unwrap()))
    }

    #[test]
    fn denoise_is_deterministic_and_seed_sensitive() {
        let (dir, reg) = registry("det");
        let stub = StubExec::new(Arc::clone(&reg)).unwrap();
        let m = reg.manifest().model.clone();
        let params = reg.manifest().load_params().unwrap();
        let native = reg.native_key();
        let x = Tensor::new(
            vec![8, m.latent_w, m.latent_c],
            NormalGen::new(3).vec_f32(8 * m.latent_w * m.latent_c),
        )
        .unwrap();
        let kv = Tensor::zeros(&m.kv_shape());
        let cond = vec![0.25f32; m.dim];
        let inp = DenoiserInputs {
            params: &params,
            x_patch: &x,
            kv_stale: &kv,
            row_off: 8,
            t: 500.0,
            cond: &cond,
        };
        let a = stub.denoise(native, 8, &inp).unwrap();
        let b = stub.denoise(native, 8, &inp).unwrap();
        assert_eq!(a.eps_patch, b.eps_patch);
        assert_eq!(a.kv_fresh, b.kv_fresh);
        assert_eq!(a.kv_fresh.shape, vec![m.layers, 64, 2 * m.dim]);
        // A different input patch changes the output.
        let x2 = Tensor::new(
            x.shape.clone(),
            NormalGen::new(4).vec_f32(x.data.len()),
        )
        .unwrap();
        let inp2 = DenoiserInputs { x_patch: &x2, ..inp.clone() };
        let c = stub.denoise(native, 8, &inp2).unwrap();
        assert!(a.eps_patch.max_abs_diff(&c.eps_patch) > 1e-4);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn non_native_resolution_checks_its_own_geometry() {
        let (dir, reg) = registry("res");
        let stub = StubExec::new(Arc::clone(&reg)).unwrap();
        let res = ResKey { h: 16, w: 32 };
        let ra = reg.get(res).unwrap();
        let m = ra.model.clone();
        let params = reg.manifest().load_params().unwrap();
        let x = Tensor::zeros(&[8, m.latent_w, m.latent_c]);
        let kv = Tensor::zeros(&m.kv_shape());
        let cond = vec![0.0f32; m.dim];
        let inp = DenoiserInputs {
            params: &params,
            x_patch: &x,
            kv_stale: &kv,
            row_off: 0,
            t: 100.0,
            cond: &cond,
        };
        let out = stub.denoise(res, 8, &inp).unwrap();
        // 8 rows at width 32: (8/2)*(32/2) = 64 own tokens.
        assert_eq!(out.kv_fresh.shape, vec![m.layers, 64, 2 * m.dim]);
        // The native KV stack (256 tokens) is the wrong shape here.
        let kv_native =
            Tensor::zeros(&reg.manifest().model.kv_shape());
        let bad = DenoiserInputs { kv_stale: &kv_native, ..inp.clone() };
        assert!(stub.denoise(res, 8, &bad).is_err());
        // row_off past the 16-row latent is rejected.
        let bad_off = DenoiserInputs { row_off: 12, ..inp };
        assert!(stub.denoise(res, 8, &bad_off).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn kv_gain_couples_eps_to_stale_kv_without_it_is_independent() {
        let (dir, reg) = registry("nogain");
        let stub = StubExec::new(Arc::clone(&reg)).unwrap();
        let dir2 = std::env::temp_dir()
            .join(format!("stadi-stubexec-gain-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir2);
        stubgen::write_stub_artifacts_full(&dir2, &[], None, Some(0.05))
            .unwrap();
        let reg2 = Arc::new(ArtifactRegistry::load(&dir2).unwrap());
        let stub2 = StubExec::new(Arc::clone(&reg2)).unwrap();

        let m = reg.manifest().model.clone();
        let params = reg.manifest().load_params().unwrap();
        let native = reg.native_key();
        let x = Tensor::new(
            vec![8, m.latent_w, m.latent_c],
            NormalGen::new(3).vec_f32(8 * m.latent_w * m.latent_c),
        )
        .unwrap();
        let kv_a = Tensor::zeros(&m.kv_shape());
        let kv_b = Tensor::new(
            m.kv_shape(),
            NormalGen::new(11).vec_f32(
                m.layers * m.tokens_full * 2 * m.dim,
            ),
        )
        .unwrap();
        let cond = vec![0.25f32; m.dim];
        let inp_a = DenoiserInputs {
            params: &params,
            x_patch: &x,
            kv_stale: &kv_a,
            row_off: 8,
            t: 500.0,
            cond: &cond,
        };
        let inp_b = DenoiserInputs { kv_stale: &kv_b, ..inp_a };
        // Without kv_gain, eps ignores the stale KV entirely.
        let a = stub.denoise(native, 8, &inp_a).unwrap();
        let b = stub.denoise(native, 8, &inp_b).unwrap();
        assert_eq!(a.eps_patch, b.eps_patch);
        // With it, a different KV context shifts eps.
        let ga = stub2.denoise(native, 8, &inp_a).unwrap();
        let gb = stub2.denoise(native, 8, &inp_b).unwrap();
        assert!(ga.eps_patch.max_abs_diff(&gb.eps_patch) > 1e-6);
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&dir2);
    }

    #[test]
    fn calibrate_produces_positive_costs() {
        let (dir, reg) = registry("calib");
        let stub = StubExec::new(reg).unwrap();
        let cost = stub.calibrate(2).unwrap();
        assert!(cost.per_row_s > 0.0);
        assert!(cost.fixed_s >= 0.0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
