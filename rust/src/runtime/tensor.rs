//! Host tensor type crossing the rust <-> PJRT boundary.

use crate::error::{Error, Result};

/// A dense f32 host tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::msg(format!(
                "shape {shape:?} wants {n} elems, got {}",
                data.len()
            )));
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Bytes on the wire (for comm accounting).
    pub fn byte_len(&self) -> usize {
        self.data.len() * 4
    }

    /// Convert to an xla literal with this tensor's shape.
    #[cfg(feature = "xla-backend")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
    }

    /// Build from an xla literal (f32 only).
    #[cfg(feature = "xla-backend")]
    pub fn from_literal(lit: &xla::Literal, shape: Vec<usize>) -> Result<Self> {
        let data = lit.to_vec::<f32>()?;
        Tensor::new(shape, data)
    }

    /// Slice rows [r0, r0+h) of a [H, W, C] tensor.
    pub fn slice_rows(&self, r0: usize, h: usize) -> Tensor {
        assert_eq!(self.shape.len(), 3, "slice_rows wants [H,W,C]");
        let (hh, w, c) = (self.shape[0], self.shape[1], self.shape[2]);
        assert!(r0 + h <= hh, "rows {r0}+{h} > {hh}");
        let stride = w * c;
        let data = self.data[r0 * stride..(r0 + h) * stride].to_vec();
        Tensor { shape: vec![h, w, c], data }
    }

    /// Scatter `patch` rows into self at row offset `r0` ([H,W,C]).
    pub fn scatter_rows(&mut self, r0: usize, patch: &Tensor) {
        assert_eq!(self.shape.len(), 3);
        assert_eq!(patch.shape.len(), 3);
        assert_eq!(self.shape[1..], patch.shape[1..]);
        let stride = self.shape[1] * self.shape[2];
        let h = patch.shape[0];
        assert!(r0 + h <= self.shape[0]);
        self.data[r0 * stride..(r0 + h) * stride]
            .copy_from_slice(&patch.data);
    }

    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }

    pub fn abs_sum(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64).abs()).sum()
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Mean squared error vs another tensor.
    pub fn mse(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        if self.data.is_empty() {
            return 0.0;
        }
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum::<f64>()
            / self.data.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::new(shape.to_vec(), (0..n).map(|i| i as f32).collect()).unwrap()
    }

    #[test]
    fn new_validates_shape() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn slice_scatter_roundtrip() {
        let full = seq(&[8, 4, 2]);
        let patch = full.slice_rows(2, 3);
        assert_eq!(patch.shape, vec![3, 4, 2]);
        assert_eq!(patch.data[0], (2 * 8) as f32);
        let mut out = Tensor::zeros(&[8, 4, 2]);
        out.scatter_rows(2, &patch);
        assert_eq!(out.slice_rows(2, 3), patch);
        assert_eq!(out.data[0], 0.0);
    }

    #[test]
    fn mse_and_diff() {
        let a = seq(&[2, 2, 1]);
        let mut b = a.clone();
        b.data[3] += 2.0;
        assert_eq!(a.max_abs_diff(&b), 2.0);
        assert!((a.mse(&b) - 1.0).abs() < 1e-12);
    }
}
