//! PJRT runtime: load AOT HLO-text artifacts and execute them from the
//! rust request path (python is build-time only).

pub mod artifacts;
pub mod client;
pub mod service;
pub mod tensor;

pub use artifacts::Manifest;
pub use client::{DenoiserInputs, DenoiserOutputs, Runtime};
pub use service::{ExecHandle, ExecService};
pub use tensor::Tensor;
