//! PJRT runtime: load AOT HLO-text artifacts and execute them from the
//! rust request path (python is build-time only). Artifact sets are
//! resolution-keyed ([`ArtifactRegistry`]); synthetic "stub" sets
//! ([`stubgen`]) execute on a deterministic offline backend
//! ([`stub_exec`]) on any build.

pub mod artifacts;
pub mod client;
pub mod service;
pub mod stub_exec;
pub mod stubgen;
pub mod tensor;

pub use artifacts::{
    ArtifactRegistry, Manifest, RegistryStats, ResKey, ResolutionArtifacts,
};
pub use client::{DenoiserInputs, DenoiserOutputs, Runtime};
pub use service::{ExecHandle, ExecService};
pub use stub_exec::StubExec;
pub use tensor::Tensor;
