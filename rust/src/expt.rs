//! Experiment support shared by the bench harness (one bench per paper
//! table/figure — see DESIGN.md §6) and the examples.

use std::path::{Path, PathBuf};

use crate::config::{CommConfig, DeviceConfig, StadiParams};
use crate::device::{build_cluster, CostModel, SimGpu};
use crate::error::Result;
use crate::runtime::ExecService;
use crate::util::json;

/// Artifacts directory relative to the crate root (benches run there).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("STADI_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// True when `make artifacts` has been run *and* this build can
/// execute them (benches early-return otherwise).
pub fn artifacts_available() -> bool {
    skip_reason().is_none()
}

/// Why a bench must skip, distinguishing the missing feature from
/// missing artifacts (so nobody re-runs `make artifacts` forever when
/// the real problem is the build flag). `None` = good to go.
pub fn skip_reason() -> Option<&'static str> {
    if !cfg!(feature = "xla-backend") {
        return Some(
            "built without the xla-backend feature — point the `xla` \
             dep in rust/Cargo.toml at real xla-rs (default: the \
             offline API stub) and build with `--features xla-backend`",
        );
    }
    if !artifacts_dir().join("manifest.json").exists() {
        return Some("artifacts not built — run `make artifacts`");
    }
    None
}

/// Load the calibrated cost model, calibrating once and caching to
/// `artifacts/calib.json` so every bench shares identical grounded
/// timings.
pub fn calibrated_cost(svc: &ExecService) -> Result<CostModel> {
    let path = artifacts_dir().join("calib.json");
    if path.exists() {
        if let Ok(v) = json::from_file(&path) {
            if let Ok(c) = CostModel::from_json(&v) {
                return Ok(c);
            }
        }
    }
    let cost = svc.handle().calibrate(5)?;
    let _ = std::fs::write(&path, json::to_string_pretty(&cost.to_json()));
    Ok(cost)
}

/// The paper's 2-GPU testbed at given occupancies, with a cost model.
pub fn cluster_with_occ(occ: &[f64], cost: CostModel) -> Vec<SimGpu> {
    let devs: Vec<DeviceConfig> = occ
        .iter()
        .enumerate()
        .map(|(i, &o)| DeviceConfig::new(format!("gpu{i}"), 1.0, o))
        .collect();
    build_cluster(&devs, cost)
}

/// Normalized effective speeds for an occupancy vector (the static
/// profiler path; benches bypass online profiling for determinism).
pub fn speeds_for_occ(occ: &[f64]) -> Vec<f64> {
    let v: Vec<f64> = occ.iter().map(|&o| 1.0 - o).collect();
    let max = v.iter().cloned().fold(0.0, f64::max);
    v.iter().map(|x| x / max).collect()
}

/// Paper §V defaults (M_base=100, warmup=4, a=0.75, b=0.25).
pub fn paper_params() -> StadiParams {
    StadiParams::default()
}

/// Default comm model (PCIe-ish, Table I testbed).
pub fn paper_comm() -> CommConfig {
    CommConfig::default()
}

/// Device names for n GPUs.
pub fn names(n: usize) -> Vec<String> {
    (0..n).map(|i| format!("gpu{i}")).collect()
}

/// Write a results file under bench_out/ (created on demand) and echo
/// the path — EXPERIMENTS.md links these.
pub fn save_results(name: &str, content: &str) -> Result<PathBuf> {
    let dir = Path::new("bench_out");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    std::fs::write(&path, content)?;
    println!("[saved {}]", path.display());
    Ok(path)
}

/// Dump a latent as an 8-bit PGM (per-channel mosaic) for the Fig. 7
/// visual-quality artifacts.
pub fn latent_to_pgm(latent: &crate::runtime::Tensor) -> Vec<u8> {
    let (h, w, c) = (latent.shape[0], latent.shape[1], latent.shape[2]);
    let lo = latent.data.iter().cloned().fold(f32::INFINITY, f32::min);
    let hi = latent.data.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let scale = if hi > lo { 255.0 / (hi - lo) } else { 1.0 };
    // Mosaic: channels side by side.
    let mut out = format!("P5\n{} {}\n255\n", w * c, h).into_bytes();
    for y in 0..h {
        for ch in 0..c {
            for x in 0..w {
                let v = latent.data[(y * w + x) * c + ch];
                out.push(((v - lo) * scale) as u8);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speeds_normalized() {
        let v = speeds_for_occ(&[0.0, 0.4]);
        assert_eq!(v, vec![1.0, 0.6]);
        let v = speeds_for_occ(&[0.5, 0.25]);
        assert!((v[0] - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(v[1], 1.0);
    }

    #[test]
    fn pgm_has_header_and_size() {
        let t = crate::runtime::Tensor::zeros(&[4, 4, 2]);
        let pgm = latent_to_pgm(&t);
        assert!(pgm.starts_with(b"P5\n8 4\n255\n"));
        assert_eq!(pgm.len(), 11 + 32);
    }
}
