//! Discrete-event simulation substrate.
//!
//! Single-core-safe timing: the latency figures (Figs. 2, 8, 9 and
//! Table III) are produced by replaying the scheduler's exact event
//! structure (step completions, sync barriers, async comm completions)
//! on a virtual clock with per-step costs calibrated from real PJRT
//! measurements (see `device::CostModel`). This module provides the
//! deterministic event queue; the replay logic lives in
//! `coordinator::timeline`.
//!
//! Determinism: ties in time break by insertion sequence number, so a
//! simulation is a pure function of its inputs.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event scheduled at a virtual time.
#[derive(Debug, Clone)]
struct Scheduled<E> {
    time: f64,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl<E> Eq for Scheduled<E> {}

impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first. NaN-free
        // by construction (schedule() asserts).
        other
            .time
            .partial_cmp(&self.time)
            .unwrap()
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic discrete-event simulator.
#[derive(Debug)]
pub struct Sim<E> {
    heap: BinaryHeap<Scheduled<E>>,
    now: f64,
    seq: u64,
    processed: u64,
}

impl<E> Default for Sim<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Sim<E> {
    pub fn new() -> Self {
        Sim { heap: BinaryHeap::new(), now: 0.0, seq: 0, processed: 0 }
    }

    /// Current virtual time (the timestamp of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn events_processed(&self) -> u64 {
        self.processed
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `event` at absolute virtual time `at` (>= now).
    pub fn schedule(&mut self, at: f64, event: E) {
        assert!(at.is_finite(), "non-finite event time");
        debug_assert!(
            at >= self.now - 1e-12,
            "scheduling into the past: {at} < {}",
            self.now
        );
        self.heap.push(Scheduled { time: at, seq: self.seq, event });
        self.seq += 1;
    }

    /// Schedule `event` `delay` seconds from now.
    pub fn schedule_in(&mut self, delay: f64, event: E) {
        let now = self.now;
        self.schedule(now + delay.max(0.0), event);
    }

    /// Pop the earliest event, advancing the clock.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        let s = self.heap.pop()?;
        self.now = s.time;
        self.processed += 1;
        Some((s.time, s.event))
    }

    /// Peek at the next event time without advancing.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|s| s.time)
    }

    /// Drain events while `f` keeps returning true; returns on empty
    /// queue or when `f` stops the run.
    pub fn run<F: FnMut(&mut Sim<E>, f64, E) -> bool>(&mut self, mut f: F) {
        while let Some((t, e)) = self.pop() {
            if !f(self, t, e) {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut sim = Sim::new();
        sim.schedule(3.0, "c");
        sim.schedule(1.0, "a");
        sim.schedule(2.0, "b");
        let order: Vec<_> = std::iter::from_fn(|| sim.pop())
            .map(|(_, e)| e)
            .collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert_eq!(sim.now(), 3.0);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut sim = Sim::new();
        sim.schedule(1.0, 1);
        sim.schedule(1.0, 2);
        sim.schedule(1.0, 3);
        let order: Vec<_> = std::iter::from_fn(|| sim.pop())
            .map(|(_, e)| e)
            .collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn schedule_in_accumulates() {
        let mut sim = Sim::new();
        sim.schedule_in(1.0, "a");
        let (t, _) = sim.pop().unwrap();
        assert_eq!(t, 1.0);
        sim.schedule_in(0.5, "b");
        let (t, _) = sim.pop().unwrap();
        assert_eq!(t, 1.5);
    }

    #[test]
    fn cascading_events_deterministic() {
        // An event chain where each event schedules the next; two runs
        // must produce identical traces.
        fn run() -> Vec<(f64, u32)> {
            let mut sim: Sim<u32> = Sim::new();
            sim.schedule(0.0, 0);
            let mut trace = Vec::new();
            sim.run(|sim, t, e| {
                trace.push((t, e));
                if e < 20 {
                    sim.schedule_in(0.1 * ((e % 3) as f64 + 1.0), e + 1);
                }
                true
            });
            trace
        }
        assert_eq!(run(), run());
    }

    #[test]
    fn run_can_stop_early() {
        let mut sim = Sim::new();
        for i in 0..10 {
            sim.schedule(i as f64, i);
        }
        let mut seen = 0;
        sim.run(|_, _, e| {
            seen += 1;
            e < 4 // e == 4 returns false and stops the run
        });
        assert_eq!(seen, 5);
        assert_eq!(sim.events_processed(), 5);
    }
}
