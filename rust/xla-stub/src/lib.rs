//! Offline API stub for `xla` (xla-rs 0.1.6) — see Cargo.toml.
//!
//! Mirrors the subset of the xla-rs API that `stadi`'s PJRT runtime
//! uses, with every runtime entry point failing loudly. The point is
//! to keep the `xla-backend` feature *compiling* in registry-less
//! environments (CI gates the API surface with `cargo check
//! --features xla-backend`); executing artifacts requires swapping
//! this path dependency for the real crate.

use std::fmt;

/// Stub error: also what every runtime entry point returns.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub_err() -> Error {
    Error(
        "xla stub: built against rust/xla-stub (offline API placeholder). \
         Point the `xla` dependency in rust/Cargo.toml at the real \
         xla-rs crate to execute artifacts"
            .into(),
    )
}

/// Element types transferable to/from literals and device buffers.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Host-side literal (stub: carries no data).
#[derive(Debug)]
pub struct Literal(());

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(stub_err())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(stub_err())
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(stub_err())
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        Err(stub_err())
    }

    pub fn to_tuple3(&self) -> Result<(Literal, Literal, Literal)> {
        Err(stub_err())
    }
}

/// Parsed HLO module proto (stub).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(stub_err())
    }
}

/// XLA computation handle (stub).
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device buffer handle (stub).
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(stub_err())
    }
}

/// Compiled executable handle (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute_b<T: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_err())
    }
}

/// PJRT client (stub: construction fails, so no other entry point is
/// ever reachable at runtime).
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(stub_err())
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(stub_err())
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        Err(stub_err())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_errors_with_the_stub_message() {
        assert!(PjRtClient::cpu().unwrap_err().to_string().contains("stub"));
        assert!(HloModuleProto::from_text_file("x").is_err());
        assert!(Literal::vec1(&[1.0f32]).to_vec::<f32>().is_err());
    }
}
