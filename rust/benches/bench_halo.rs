//! EXTENSION: displaced halo exchange — micro-bench + makespan sweep.
//!
//! Part 1 (the sigalign `to_json` bench-group idiom: same input, every
//! implementation variant timed side by side): the same uneven
//! boundary payloads pushed through both exchange paths on the real
//! `CollectiveBus` — pack + blocking `all_gather` vs the displaced
//! pack + `publish` + barrier + `peek` protocol the threaded executor
//! runs. The displaced path never waits on the *payload*, only on the
//! empty barrier, which is the mechanism the timeline model charges.
//!
//! Part 2: the timeline model's sync-vs-displaced makespan sweep per
//! staleness budget on the slow-interconnect fixture (comm-bound under
//! sync), asserting the displaced win the integration test pins.
//!
//! Results land in bench_out/BENCH_halo.json; the repo root carries a
//! committed copy (see scripts/gen_bench_artifacts.py) so the perf
//! trajectory survives re-anchors. Unlike the artifact-driven benches
//! this one has no skip path: everything here is std-only.

use std::thread;

use stadi::comm::{
    all_gather_cost, displaced_exchange_cost, CollectiveBus,
};
use stadi::config::{
    CommConfig, HaloMode, StadiParams, UnevenStrategy,
};
use stadi::coordinator::timeline;
use stadi::device::CostModel;
use stadi::expt;
use stadi::model::schedule::Schedule;
use stadi::runtime::artifacts::ModelInfo;
use stadi::sched::plan::Plan;
use stadi::util::benchkit::{bench, fmt_secs, Sample, Table};
use stadi::util::json::{self, Object, Value};

/// The stub backend's model geometry (runtime/stubgen.rs), spelled out
/// so the sweep runs without generated artifacts.
fn stub_model() -> ModelInfo {
    ModelInfo {
        latent_h: 32,
        latent_w: 32,
        latent_c: 4,
        patch: 2,
        dim: 16,
        heads: 2,
        layers: 2,
        temb_dim: 8,
        row_granularity: 4,
        tokens_full: 256,
        param_count: 64,
        params_seed: 7,
    }
}

/// f32 elements of one device's x-halo payload for `rows` rows (the
/// executors ship rows * latent_w * latent_c floats = rows * 512 B).
fn halo_elems(rows: usize) -> usize {
    rows * 32 * 4
}

fn sample_json(s: &Sample) -> Value {
    let mut o = Object::new();
    o.insert("label", Value::Str(s.label.clone()));
    o.insert("iters", Value::Num(s.iters as f64));
    o.insert("mean_s", Value::Num(s.mean_s));
    o.insert("p50_s", Value::Num(s.p50_s));
    o.insert("std_s", Value::Num(s.std_s));
    Value::Obj(o)
}

fn main() -> stadi::Result<()> {
    // ---- Part 1: pack/publish/peek vs blocking all_gather ----------
    println!("# halo micro-bench: blocking gather vs displaced publish");
    let splits: [(usize, usize); 3] = [(16, 16), (24, 8), (28, 4)];
    let source = vec![0.5f32; 32 * 32 * 4];
    let mut table =
        Table::new(&["rows", "blocking gather", "publish+peek", "ratio"]);
    let mut micro = Vec::new();
    for &(r0, r1) in &splits {
        let rows = [r0, r1];
        // Both variants pack each rank's boundary rows from the same
        // source latent; only the exchange differs.
        let run_pair = |displaced: bool| {
            let bus = CollectiveBus::new();
            let mut handles = Vec::new();
            for rank in 0..2usize {
                let bus = bus.clone();
                let source = source.clone();
                let n = halo_elems(rows[rank]);
                handles.push(thread::spawn(move || -> usize {
                    let payload: Vec<f32> = source[..n].to_vec();
                    if displaced {
                        bus.publish(rank, "halo", payload);
                        // The executor's empty barrier: ranks agree a
                        // sync point happened without waiting on the
                        // payload bytes.
                        bus.all_gather("barrier", rank, &[0, 1], Vec::new())
                            .unwrap();
                        bus.peek(1 - rank, "halo")
                            .map(|d| d.len())
                            .unwrap_or(0)
                    } else {
                        let m = bus
                            .all_gather("x", rank, &[0, 1], payload)
                            .unwrap();
                        m.values().map(|v| v.len()).sum()
                    }
                }));
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("worker"))
                .sum::<usize>()
        };
        let mut sink = 0usize;
        let blocking = bench(format!("gather {r0}:{r1}"), 3, 30, || {
            sink += run_pair(false);
        });
        let displaced = bench(format!("publish {r0}:{r1}"), 3, 30, || {
            sink += run_pair(true);
        });
        assert!(sink > 0, "exchange produced no data");
        table.row(&[
            format!("{r0}:{r1}"),
            fmt_secs(blocking.mean_s),
            fmt_secs(displaced.mean_s),
            format!("{:.2}x", blocking.mean_s / displaced.mean_s),
        ]);
        let mut entry = Object::new();
        entry.insert("split", Value::Str(format!("{r0}:{r1}")));
        entry.insert("blocking", sample_json(&blocking));
        entry.insert("displaced", sample_json(&displaced));
        micro.push(Value::Obj(entry));
    }
    table.print();

    // The cost model prices both paths identically per exchange — the
    // win is *charging* (overlap), not cheaper bytes.
    for strategy in
        [UnevenStrategy::PadAllGather, UnevenStrategy::MultiBroadcast]
    {
        let cfg = CommConfig {
            latency_s: 0.02,
            bandwidth_bytes_per_s: 2e7,
            uneven_strategy: strategy,
        };
        for (r0, r1) in splits {
            let sizes = [halo_elems(r0) * 4, halo_elems(r1) * 4];
            assert_eq!(
                displaced_exchange_cost(&cfg, &sizes),
                all_gather_cost(&cfg, &sizes),
            );
        }
    }

    // ---- Part 2: makespan sweep per staleness budget ---------------
    println!("\n# makespan sweep: slow interconnect, budgets 0..=3");
    let model = stub_model();
    let schedule = Schedule::scaled_linear(1000, 0.00085, 0.012);
    let params =
        StadiParams { m_base: 16, m_warmup: 2, ..Default::default() };
    let comm = CommConfig {
        latency_s: 0.02,
        bandwidth_bytes_per_s: 2e7,
        uneven_strategy: UnevenStrategy::PadAllGather,
    };
    let occ = [0.0, 0.5];
    let cluster = expt::cluster_with_occ(&occ, CostModel::uncalibrated());
    let speeds = expt::speeds_for_occ(&occ);
    let plan = Plan::build(
        &schedule,
        &speeds,
        &expt::names(2),
        &params,
        model.latent_h,
        model.row_granularity,
    )?;
    let sync = timeline::simulate(&plan, &cluster, &comm, &model)?;
    println!(
        "# sync: total {} comm {} ({:.0}% comm-bound)",
        fmt_secs(sync.total_s),
        fmt_secs(sync.comm_s),
        100.0 * sync.comm_s / sync.total_s
    );
    assert!(
        sync.comm_s > 0.2 * sync.total_s,
        "fixture not comm-bound under sync"
    );
    let mut stable = Table::new(&[
        "budget", "total", "comm", "displaced", "fallback", "vs sync",
    ]);
    let mut sweep = Vec::new();
    for budget in 0..=3usize {
        let tl = timeline::simulate_with(
            &plan,
            &cluster,
            &comm,
            &model,
            HaloMode::Displaced { max_staleness: budget },
        )?;
        if budget == 0 {
            assert_eq!(tl.total_s, sync.total_s, "budget 0 must be sync");
        } else {
            assert!(
                tl.total_s < sync.total_s,
                "budget {budget}: {} !< sync {}",
                tl.total_s,
                sync.total_s
            );
        }
        // Note: the sweep is NOT monotone in the budget. Budget b
        // forces the first b sync points to fall back (the plan needs
        // that much history before halos may go stale), so a larger
        // budget trades a longer synchronous prefix for looser
        // deadlines — and once every debt is already fully masked by
        // the next interval's compute, the extra slack buys nothing.
        // The sweep records that trade-off instead of asserting it
        // away.
        stable.row(&[
            format!("{budget}"),
            fmt_secs(tl.total_s),
            fmt_secs(tl.comm_s),
            format!("{}", tl.halo_displaced),
            format!("{}", tl.halo_fallback),
            format!("-{:.1}%", 100.0 * (1.0 - tl.total_s / sync.total_s)),
        ]);
        let mut e = Object::new();
        e.insert("budget", Value::Num(budget as f64));
        e.insert("total_s", Value::Num(tl.total_s));
        e.insert("comm_s", Value::Num(tl.comm_s));
        e.insert("displaced", Value::Num(tl.halo_displaced as f64));
        e.insert("fallback", Value::Num(tl.halo_fallback as f64));
        e.insert(
            "speedup_vs_sync",
            Value::Num(sync.total_s / tl.total_s),
        );
        sweep.push(Value::Obj(e));
    }
    stable.print();

    let mut halo = Object::new();
    halo.insert("latency_s", Value::Num(comm.latency_s));
    halo.insert(
        "bandwidth_bytes_per_s",
        Value::Num(comm.bandwidth_bytes_per_s),
    );
    halo.insert("occupancy", Value::Str(format!("{occ:?}")));
    halo.insert("sync_total_s", Value::Num(sync.total_s));
    halo.insert("sync_comm_s", Value::Num(sync.comm_s));
    halo.insert("sweep", Value::Arr(sweep));
    let mut out = Object::new();
    out.insert("bench", Value::Str("halo_exchange".into()));
    out.insert("micro", Value::Arr(micro));
    out.insert("halo", Value::Obj(halo));
    expt::save_results(
        "BENCH_halo.json",
        &json::to_string_pretty(&Value::Obj(out)),
    )?;
    Ok(())
}
