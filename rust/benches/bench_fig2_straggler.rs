//! Fig. 2 reproduction: patch parallelism's end-to-end latency is
//! constrained by the most-occupied device (straggler effect).
//!
//! Paper setup: 2 GPUs, occupancy on GPU1 swept {0, 20, 40, 60, 80}%,
//! DistriFusion-style patch parallelism. Expectation (shape): latency
//! grows superlinearly in occupancy — ~1/(1-rho) — because per-step
//! sync pins the cluster to the straggler.

use stadi::baselines::patch_parallel;
use stadi::coordinator::timeline;
use stadi::expt;
use stadi::model::schedule::Schedule;
use stadi::runtime::ExecService;
use stadi::util::benchkit::Table;

fn main() -> stadi::Result<()> {
    if let Some(reason) = expt::skip_reason() {
        eprintln!("skipping: {reason}");
        return Ok(());
    }
    let svc = ExecService::spawn(expt::artifacts_dir())?;
    let model = svc.handle().manifest().model.clone();
    let schedule = Schedule::from_info(&svc.handle().manifest().schedule);
    let cost = expt::calibrated_cost(&svc)?;
    let params = expt::paper_params();
    let comm = expt::paper_comm();

    println!(
        "# Fig. 2 — patch-parallel latency vs background occupancy \
         (2x GPUs, M={}, calibrated step cost fixed={:.2}ms \
         per_row={:.3}ms)",
        params.m_base,
        cost.fixed_s * 1e3,
        cost.per_row_s * 1e3
    );

    let pp_plan = patch_parallel::plan(
        &schedule, 2, &params, model.latent_h, model.row_granularity,
    )?;

    let mut table = Table::new(&[
        "occupancy", "latency(s)", "vs idle", "straggler step ratio",
    ]);
    let mut rows = String::new();
    let mut base = 0.0f64;
    for occ in [0.0, 0.2, 0.4, 0.6, 0.8] {
        let cluster = expt::cluster_with_occ(&[0.0, occ], cost);
        let tl = timeline::simulate(&pp_plan, &cluster, &comm, &model)?;
        if occ == 0.0 {
            base = tl.total_s;
        }
        table.row(&[
            format!("{:.0}%", occ * 100.0),
            format!("{:.3}", tl.total_s),
            format!("{:.2}x", tl.total_s / base),
            format!("{:.2}", 1.0 / (1.0 - occ)),
        ]);
        rows.push_str(&format!("{occ} {}\n", tl.total_s));
    }
    table.print();
    println!(
        "\nshape check: latency ratio should track the straggler's \
         1/(1-occ) slowdown (paper Fig. 2 shows the same blow-up on \
         real 4090s)."
    );
    expt::save_results("fig2_straggler.dat", &rows)?;
    Ok(())
}
