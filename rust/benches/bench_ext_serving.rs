//! EXTENSION: serving-level impact — how the scheduler's single-
//! request gains compound under load, and what the concurrent serve
//! stack buys on top.
//!
//! Five measurements:
//! 1. M/G/1 queueing (DES): STADI vs patch-parallel service times
//!    under Poisson load — near saturation the sojourn-time gap far
//!    exceeds the raw service-time gap (rho/(1-rho) amplification).
//! 2. M/G/c queueing (DES): the same STADI service time with a worker
//!    pool of 1/2/4 — concurrency lifts the capacity ceiling.
//! 3. Gang-policy sweep (DES over the real FleetManager + planner):
//!    all/fixed:2/adaptive on a 4-GPU heterogeneous fleet — the
//!    latency-vs-throughput frontier of fleet partitioning.
//! 4. Mixed-size / mixed-priority workload sweep (DES): small urgent
//!    draft requests (per-spec planner pricing: fewer steps, fewer
//!    latent rows) sharing the fleet with heavy batch requests, FIFO
//!    vs the v2 priority/deadline router — emitted as
//!    bench_out/BENCH_serving.json to start the perf trajectory, and
//!    asserted: the priority router meets strictly more deadlines at
//!    2x load.
//! 5. Real TCP concurrency sweep: the actual server (accept loop +
//!    worker pool + sessions on one shared core) driven by 1/2/4
//!    concurrent client connections, measuring end-to-end throughput
//!    and client-side p50/p95 latency.

use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;

use stadi::baselines::patch_parallel;
use stadi::config::EngineConfig;
use stadi::coordinator::{timeline, EngineCore};
use stadi::expt;
use stadi::fleet::{Adaptive, AllGpus, FixedGang, GangPolicy};
use stadi::model::schedule::Schedule;
use stadi::runtime::ExecService;
use stadi::sched::plan::Plan;
use stadi::serve::server::{drive_workload, serve, ServeOptions};
use stadi::serve::sim::{
    assert_leases_disjoint, simulate_gang_policy, simulate_mixed_workload,
    simulate_open_loop, simulate_open_loop_servers, Discipline,
    WorkloadClass,
};
use stadi::spec::Priority;
use stadi::util::benchkit::Table;
use stadi::util::json::{self, Object, Value};
use stadi::util::plot::{render, Series};

fn main() -> stadi::Result<()> {
    if let Some(reason) = expt::skip_reason() {
        eprintln!("skipping: {reason}");
        return Ok(());
    }
    let svc = ExecService::spawn(expt::artifacts_dir())?;
    let model = svc.handle().manifest().model.clone();
    let schedule = Schedule::from_info(&svc.handle().manifest().schedule);
    let cost = expt::calibrated_cost(&svc)?;
    let comm = expt::paper_comm();
    let params = expt::paper_params();

    let occ = [0.0, 0.5];
    let cluster = expt::cluster_with_occ(&occ, cost);
    let speeds = expt::speeds_for_occ(&occ);

    let pp_plan = patch_parallel::plan(
        &schedule, 2, &params, model.latent_h, model.row_granularity,
    )?;
    let s_pp = timeline::simulate(&pp_plan, &cluster, &comm, &model)?
        .total_s;
    let stadi_plan = Plan::build(
        &schedule,
        &speeds,
        &expt::names(2),
        &params,
        model.latent_h,
        model.row_granularity,
    )?;
    let s_st = timeline::simulate(&stadi_plan, &cluster, &comm, &model)?
        .total_s;
    println!(
        "# serving under load, occ [0%,50%]: service PP={s_pp:.3}s \
         STADI={s_st:.3}s ({:.1}% faster)",
        (1.0 - s_st / s_pp) * 100.0
    );
    // Displaced-halo pricing of the same plan: the committed perf
    // trajectory records both charges so re-anchors can see how much
    // comm headroom displacement buys on this testbed.
    let s_st_disp = timeline::simulate_with(
        &stadi_plan,
        &cluster,
        &comm,
        &model,
        stadi::config::HaloMode::Displaced { max_staleness: 1 },
    )?
    .total_s;
    assert!(
        s_st_disp <= s_st + 1e-12,
        "displaced charging made the plan slower: {s_st_disp} vs {s_st}"
    );
    let halo_entry = || {
        let mut h = Object::new();
        h.insert("mode", Value::Str("displaced:1".into()));
        h.insert("sync_total_s", Value::Num(s_st));
        h.insert("displaced_total_s", Value::Num(s_st_disp));
        h.insert("speedup_vs_sync", Value::Num(s_st / s_st_disp));
        Value::Obj(h)
    };

    let n_requests = 600;
    let mut table = Table::new(&[
        "arrival rps", "rho(PP)", "PP p95 sojourn", "rho(STADI)",
        "STADI p95 sojourn", "p95 gain",
    ]);
    let mut series_pp = Series::new("PP", 'o');
    let mut series_st = Series::new("STADI", '#');
    let mut dat = String::new();
    // Sweep up to just under STADI's saturation point.
    let max_rate = 0.95 / s_st;
    for i in 1..=6 {
        let rate = max_rate * i as f64 / 6.0;
        let q_pp = simulate_open_loop(rate, n_requests, &[s_pp], 11);
        let q_st = simulate_open_loop(rate, n_requests, &[s_st], 11);
        let gain = (1.0 - q_st.p95_sojourn_s / q_pp.p95_sojourn_s) * 100.0;
        table.row(&[
            format!("{rate:.2}"),
            format!("{:.2}", rate * s_pp),
            format!("{:.2}s", q_pp.p95_sojourn_s),
            format!("{:.2}", rate * s_st),
            format!("{:.2}s", q_st.p95_sojourn_s),
            format!("-{gain:.0}%"),
        ]);
        series_pp.push(rate, q_pp.p95_sojourn_s);
        series_st.push(rate, q_st.p95_sojourn_s);
        dat.push_str(&format!(
            "{rate} {} {}\n",
            q_pp.p95_sojourn_s, q_st.p95_sojourn_s
        ));
        // STADI must dominate; the gap must exceed the raw service
        // gap once PP nears saturation.
        assert!(q_st.p95_sojourn_s <= q_pp.p95_sojourn_s + 1e-9);
        if rate * s_pp > 0.9 {
            assert!(
                gain / 100.0 > (1.0 - s_st / s_pp),
                "queueing should amplify the service-time gap"
            );
        }
    }
    table.print();
    println!("\np95 sojourn vs arrival rate:");
    print!("{}", render(&[series_pp, series_st], 60, 12));
    expt::save_results("ext_serving.dat", &dat)?;

    // --- M/G/c: what a worker pool buys at fixed service time -------
    println!("\n# worker-pool queueing (STADI service time, DES)");
    let mut ctable = Table::new(&[
        "workers", "arrival rps", "rho", "mean wait", "p95 sojourn",
        "throughput rps",
    ]);
    let rate = 1.5 / s_st; // 1.5x one worker's capacity
    let mut cdat = String::new();
    let mut thr_by_c = Vec::new();
    for c in [1usize, 2, 4] {
        let q = simulate_open_loop_servers(rate, n_requests, &[s_st], c, 13);
        ctable.row(&[
            format!("{c}"),
            format!("{rate:.2}"),
            format!("{:.2}", q.offered_load),
            format!("{:.2}s", q.mean_wait_s),
            format!("{:.2}s", q.p95_sojourn_s),
            format!("{:.2}", q.throughput_rps),
        ]);
        cdat.push_str(&format!(
            "{c} {} {} {}\n",
            q.mean_wait_s, q.p95_sojourn_s, q.throughput_rps
        ));
        thr_by_c.push(q.throughput_rps);
    }
    ctable.print();
    expt::save_results("ext_serving_workers.dat", &cdat)?;
    // Overloaded single worker -> 2 workers must raise throughput.
    assert!(
        thr_by_c[1] > 1.2 * thr_by_c[0],
        "2 sim workers should beat 1 under overload"
    );

    // --- Gang-policy sweep: fleet partitioning (DES) ----------------
    println!("\n# gang-policy sweep: 4-GPU heterogeneous fleet (DES)");
    let occ4 = [0.0, 0.1, 0.2, 0.5];
    let cluster4 = expt::cluster_with_occ(&occ4, cost);
    let speeds4 = expt::speeds_for_occ(&occ4);
    // Per-gang latency from the real Eq. 4/5 planner + timeline —
    // admission decisions and reported numbers share one model.
    let latency_of = |gang: &[usize]| -> Option<f64> {
        let sp: Vec<f64> = gang.iter().map(|&d| speeds4[d]).collect();
        let nm: Vec<String> =
            gang.iter().map(|&d| format!("gpu{d}")).collect();
        let plan = Plan::build(
            &schedule, &sp, &nm, &params, model.latent_h,
            model.row_granularity,
        )
        .ok()?;
        let sub: Vec<_> =
            gang.iter().map(|&d| cluster4[d].clone()).collect();
        timeline::simulate(&plan, &sub, &comm, &model)
            .ok()
            .map(|t| t.total_s)
    };
    let policies: Vec<Box<dyn GangPolicy>> = vec![
        Box::new(AllGpus),
        Box::new(FixedGang(2)),
        Box::new(Adaptive::default()),
    ];
    let single_all =
        simulate_gang_policy(1.0, 1, &speeds4, &AllGpus, &latency_of, 21)
            .mean_service_s;
    let rate4 = 2.0 / single_all; // 2x the whole-fleet capacity
    let mut gtable = Table::new(&[
        "policy", "1-req latency", "loaded thr rps", "p95 sojourn",
        "mean gang",
    ]);
    let mut gdat = String::new();
    let mut thr_by_policy = Vec::new();
    for p in &policies {
        let single = simulate_gang_policy(
            1.0, 1, &speeds4, p.as_ref(), &latency_of, 21,
        )
        .mean_service_s;
        let loaded = simulate_gang_policy(
            rate4, 200, &speeds4, p.as_ref(), &latency_of, 23,
        );
        // Partitioning must never double-book a GPU.
        assert_leases_disjoint(&loaded.leases);
        gtable.row(&[
            loaded.policy.clone(),
            format!("{single:.3}s"),
            format!("{:.3}", loaded.throughput_rps),
            format!("{:.2}s", loaded.p95_sojourn_s),
            format!("{:.2}", loaded.mean_gang_size),
        ]);
        gdat.push_str(&format!(
            "{} {single} {} {} {}\n",
            loaded.policy,
            loaded.throughput_rps,
            loaded.p95_sojourn_s,
            loaded.mean_gang_size
        ));
        thr_by_policy.push((loaded.policy.clone(), loaded.throughput_rps));
    }
    gtable.print();
    expt::save_results("ext_serving_gang_policies.dat", &gdat)?;
    // The adaptive policy must convert partitioning into throughput.
    let thr_all = thr_by_policy[0].1;
    let thr_adaptive = thr_by_policy[2].1;
    assert!(
        thr_adaptive > thr_all,
        "adaptive {thr_adaptive} rps should beat AllGpus {thr_all} rps \
         under 2x load"
    );

    // --- Mixed-size / mixed-priority sweep: FIFO vs priority/EDF ----
    println!("\n# mixed workload: FIFO vs priority/deadline router (DES)");
    // Two request shapes priced by the real planner: a draft-quality
    // half-height interactive request vs a full native batch request —
    // per-spec planning is what makes their costs differ.
    let service_of = |steps: usize, rows: usize| -> stadi::Result<f64> {
        let p = params.for_steps(steps);
        let plan = Plan::build(
            &schedule, &speeds, &expt::names(2), &p, rows,
            model.row_granularity,
        )?;
        Ok(timeline::simulate(&plan, &cluster, &comm, &model)?.total_s)
    };
    let s_small = service_of(50, model.latent_h / 2)?;
    let s_large = service_of(params.m_base, model.latent_h)?;
    println!(
        "# per-spec pricing: interactive (50 steps, {} rows) = \
         {s_small:.3}s, batch ({} steps, {} rows) = {s_large:.3}s",
        model.latent_h / 2,
        params.m_base,
        model.latent_h
    );
    assert!(
        s_small < 0.75 * s_large,
        "spec-shaped planning should price the small request well \
         below the native one ({s_small} vs {s_large})"
    );
    let classes = vec![
        WorkloadClass {
            name: "interactive".into(),
            weight: 0.5,
            service_s: s_small,
            priority: Priority::High.rank(),
            deadline_s: Some(4.0 * s_small),
            resolution: Some((model.latent_h * 4, model.latent_w * 8)),
        },
        WorkloadClass {
            name: "batch".into(),
            weight: 0.5,
            service_s: s_large,
            priority: Priority::Low.rank(),
            deadline_s: None,
            resolution: Some((model.latent_h * 8, model.latent_w * 8)),
        },
    ];
    let servers = 2usize;
    let mean_service = 0.5 * s_small + 0.5 * s_large;
    let mut mtable = Table::new(&[
        "load", "fifo met", "prio met", "fifo hi p95", "prio hi p95",
        "prio shed",
    ]);
    let mut sweep = Vec::new();
    let mut at_2x = None;
    for load_x in [0.5f64, 1.0, 2.0] {
        let rate = load_x * servers as f64 / mean_service;
        let fifo = simulate_mixed_workload(
            rate, 400, &classes, Discipline::Fifo, servers, 29,
        );
        let prio = simulate_mixed_workload(
            rate, 400, &classes, Discipline::PriorityEdf, servers, 29,
        );
        mtable.row(&[
            format!("{load_x:.1}x"),
            format!("{}/{}", fifo.deadlines_met, fifo.deadlines_total),
            format!("{}/{}", prio.deadlines_met, prio.deadlines_total),
            format!("{:.2}s", fifo.class("interactive").p95_sojourn_s),
            format!("{:.2}s", prio.class("interactive").p95_sojourn_s),
            format!("{}", prio.shed),
        ]);
        let mut entry = Object::new();
        entry.insert("load_x", Value::Num(load_x));
        entry.insert("rate_rps", Value::Num(rate));
        for (key, s) in [("fifo", &fifo), ("priority", &prio)] {
            let mut d = Object::new();
            d.insert("deadlines_met", Value::Num(s.deadlines_met as f64));
            d.insert(
                "deadlines_total",
                Value::Num(s.deadlines_total as f64),
            );
            d.insert("shed", Value::Num(s.shed as f64));
            d.insert(
                "hi_p95_sojourn_s",
                Value::Num(s.class("interactive").p95_sojourn_s),
            );
            d.insert("throughput_rps", Value::Num(s.throughput_rps));
            entry.insert(key, Value::Obj(d));
        }
        sweep.push(Value::Obj(entry));
        if load_x == 2.0 {
            at_2x = Some((fifo, prio));
        }
    }
    mtable.print();
    let mut bench = Object::new();
    bench.insert("bench", Value::Str("serving_mixed_workload".into()));
    bench.insert("service_interactive_s", Value::Num(s_small));
    bench.insert("service_batch_s", Value::Num(s_large));
    bench.insert("servers", Value::Num(servers as f64));
    bench.insert("sweep", Value::Arr(sweep));
    bench.insert("halo", halo_entry());
    expt::save_results(
        "BENCH_serving.json",
        &json::to_string_pretty(&Value::Obj(bench)),
    )?;
    // Acceptance criterion: at 2x load the v2 priority/deadline router
    // meets strictly more deadlines than FIFO and wins high-priority
    // p95.
    let (fifo2, prio2) = at_2x.expect("2x point swept");
    assert!(
        prio2.deadlines_met > fifo2.deadlines_met,
        "priority router met {} vs FIFO {} at 2x load",
        prio2.deadlines_met,
        fifo2.deadlines_met
    );
    assert!(
        prio2.class("interactive").p95_sojourn_s
            < fifo2.class("interactive").p95_sojourn_s,
        "priority router must win high-priority p95 at 2x load"
    );

    // --- Mixed-resolution sweep: planner-priced sizes (DES) ---------
    println!("\n# mixed-resolution workload: per-size planner pricing");
    let mut cfg =
        EngineConfig::two_gpu_default(expt::artifacts_dir(), &[0.0, 0.5]);
    cfg.stadi.m_base = 8;
    cfg.stadi.m_warmup = 2;
    let core = EngineCore::new(cfg)?;
    // Three request sizes priced by the engine's own predictor (the
    // same tokens-ratio scaling the gang policies see): a half-height
    // interactive size, the native size, and a 1.5x "high-res" size.
    let native_px = (model.latent_h * 8, model.latent_w * 8);
    let size_specs = [
        ("interactive", native_px.0 / 2, native_px.1, 2u8, true),
        ("native", native_px.0, native_px.1, 1u8, false),
        ("hires", native_px.0 * 3 / 2, native_px.1, 0u8, false),
    ];
    let mut res_classes = Vec::new();
    let mut priced = Vec::new();
    for &(name, hpx, wpx, prio, with_deadline) in &size_specs {
        let spec = stadi::spec::GenerationSpec::new().size(hpx, wpx);
        let s = core.predict_latency_for(&spec, &[0, 1])?;
        println!("#   {name} ({hpx}x{wpx}px): predicted {s:.3}s");
        priced.push(s);
        res_classes.push(WorkloadClass {
            name: name.into(),
            weight: 1.0 / size_specs.len() as f64,
            service_s: s,
            priority: prio,
            deadline_s: if with_deadline { Some(4.0 * s) } else { None },
            resolution: Some((hpx, wpx)),
        });
    }
    // The predictor must price sizes monotonically: more rows (and
    // more tokens per row) never gets cheaper.
    assert!(
        priced[0] < priced[1] && priced[1] < priced[2],
        "resolution pricing not monotone: {priced:?}"
    );
    let mean_res_service = priced.iter().sum::<f64>() / priced.len() as f64;
    let mut mr_sweep = Vec::new();
    for load_x in [0.5f64, 1.0, 2.0] {
        let rate = load_x * servers as f64 / mean_res_service;
        let mut entry = Object::new();
        entry.insert("load_x", Value::Num(load_x));
        entry.insert("rate_rps", Value::Num(rate));
        let mut at_load = Vec::new();
        for d in [Discipline::Fifo, Discipline::PriorityEdf] {
            let s = simulate_mixed_workload(
                rate, 400, &res_classes, d, servers, 31,
            );
            at_load.push(s.clone());
            let key = match d {
                Discipline::Fifo => "fifo",
                Discipline::PriorityEdf => "priority",
            };
            entry.insert(key, s.to_json());
        }
        // At overload the priority/EDF router must not lose deadlines
        // to FIFO on the mixed-resolution mix either.
        if load_x >= 2.0 {
            assert!(
                at_load[1].deadlines_met >= at_load[0].deadlines_met,
                "priority router lost deadlines on the resolution mix"
            );
            assert!(
                at_load[1].class("interactive").p95_sojourn_s
                    <= at_load[0].class("interactive").p95_sojourn_s,
                "priority router lost interactive p95 on the \
                 resolution mix"
            );
        }
        mr_sweep.push(Value::Obj(entry));
    }
    let mut mr_bench = Object::new();
    mr_bench.insert("bench", Value::Str("serving_mixed_resolution".into()));
    mr_bench.insert("servers", Value::Num(servers as f64));
    mr_bench.insert(
        "mean_service_s",
        Value::Num(mean_res_service),
    );
    mr_bench.insert("sweep", Value::Arr(mr_sweep));
    mr_bench.insert("halo", halo_entry());
    expt::save_results(
        "BENCH_multires.json",
        &json::to_string_pretty(&Value::Obj(mr_bench)),
    )?;

    // --- Real TCP sweep: 1/2/4 in-flight requests end to end --------
    println!("\n# real server: throughput vs in-flight requests");
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let server = {
        let stop = Arc::clone(&stop);
        thread::spawn(move || {
            serve(
                core,
                listener,
                ServeOptions {
                    queue_capacity: 32,
                    workers: 4,
                    max_requests: 0,
                    ..ServeOptions::default()
                },
                Some(stop),
            )
        })
    };

    let total = 24usize;
    let mut rtable = Table::new(&[
        "in-flight", "requests", "wall (s)", "req/s", "p50 lat", "p95 lat",
    ]);
    let mut rdat = String::new();
    let mut throughput = Vec::new();
    // Warm the artifact cache off the measured path.
    drive_workload(&addr, 1, 2, 1)?;
    for clients in [1usize, 2, 4] {
        let w = drive_workload(&addr, clients, total / clients, 7000)?;
        let thr = w.throughput_rps(total);
        rtable.row(&[
            format!("{clients}"),
            format!("{total}"),
            format!("{:.2}", w.wall_s),
            format!("{thr:.2}"),
            format!("{:.3}s", w.p50_latency_s),
            format!("{:.3}s", w.p95_latency_s),
        ]);
        rdat.push_str(&format!(
            "{clients} {} {thr} {} {}\n",
            w.wall_s, w.p50_latency_s, w.p95_latency_s
        ));
        throughput.push(thr);
    }
    rtable.print();
    expt::save_results("ext_serving_concurrency.dat", &rdat)?;
    let best = throughput[1].max(throughput[2]);
    println!(
        "# concurrency gain: best {:.2} req/s vs sequential {:.2} req/s \
         ({:.2}x)",
        best,
        throughput[0],
        best / throughput[0]
    );
    // On multi-core hosts concurrent serving wins outright (sessions
    // overlap around the PJRT service thread); on a single-core or
    // heavily loaded host context-switching can legitimately eat the
    // gain, so warn rather than abort and lose the results above.
    if best < 0.9 * throughput[0] {
        eprintln!(
            "warning: concurrent serving lost throughput on this host: \
             {throughput:?} (constrained/oversubscribed machine?)"
        );
    }

    stop.store(true, Ordering::SeqCst);
    server.join().expect("server thread")?;
    Ok(())
}
