//! EXTENSION: serving-level impact — how the scheduler's single-
//! request gains compound under load (M/G/1 queueing on the DES
//! substrate; see `serve::sim`).
//!
//! Service times come from the calibrated timeline simulation of each
//! scheduler on the [0%, 50%] 2-GPU cluster; arrivals are Poisson at a
//! sweep of rates. Near saturation the sojourn-time gap between STADI
//! and patch parallelism far exceeds the raw service-time gap — the
//! classic rho/(1-rho) amplification.

use stadi::baselines::patch_parallel;
use stadi::coordinator::timeline;
use stadi::expt;
use stadi::model::schedule::Schedule;
use stadi::runtime::ExecService;
use stadi::sched::plan::Plan;
use stadi::serve::sim::simulate_open_loop;
use stadi::util::benchkit::Table;
use stadi::util::plot::{render, Series};

fn main() -> stadi::Result<()> {
    if !expt::artifacts_available() {
        eprintln!("artifacts not built — run `make artifacts`");
        return Ok(());
    }
    let svc = ExecService::spawn(expt::artifacts_dir())?;
    let model = svc.handle().manifest().model.clone();
    let schedule = Schedule::from_info(&svc.handle().manifest().schedule);
    let cost = expt::calibrated_cost(&svc)?;
    let comm = expt::paper_comm();
    let params = expt::paper_params();

    let occ = [0.0, 0.5];
    let cluster = expt::cluster_with_occ(&occ, cost);
    let speeds = expt::speeds_for_occ(&occ);

    let pp_plan = patch_parallel::plan(
        &schedule, 2, &params, model.latent_h, model.row_granularity,
    )?;
    let s_pp = timeline::simulate(&pp_plan, &cluster, &comm, &model)?
        .total_s;
    let stadi_plan = Plan::build(
        &schedule,
        &speeds,
        &expt::names(2),
        &params,
        model.latent_h,
        model.row_granularity,
    )?;
    let s_st = timeline::simulate(&stadi_plan, &cluster, &comm, &model)?
        .total_s;
    println!(
        "# serving under load, occ [0%,50%]: service PP={s_pp:.3}s \
         STADI={s_st:.3}s ({:.1}% faster)",
        (1.0 - s_st / s_pp) * 100.0
    );

    let n_requests = 600;
    let mut table = Table::new(&[
        "arrival rps", "rho(PP)", "PP p95 sojourn", "rho(STADI)",
        "STADI p95 sojourn", "p95 gain",
    ]);
    let mut series_pp = Series::new("PP", 'o');
    let mut series_st = Series::new("STADI", '#');
    let mut dat = String::new();
    // Sweep up to just under STADI's saturation point.
    let max_rate = 0.95 / s_st;
    for i in 1..=6 {
        let rate = max_rate * i as f64 / 6.0;
        let q_pp = simulate_open_loop(rate, n_requests, &[s_pp], 11);
        let q_st = simulate_open_loop(rate, n_requests, &[s_st], 11);
        let gain = (1.0 - q_st.p95_sojourn_s / q_pp.p95_sojourn_s) * 100.0;
        table.row(&[
            format!("{rate:.2}"),
            format!("{:.2}", rate * s_pp),
            format!("{:.2}s", q_pp.p95_sojourn_s),
            format!("{:.2}", rate * s_st),
            format!("{:.2}s", q_st.p95_sojourn_s),
            format!("-{gain:.0}%"),
        ]);
        series_pp.push(rate, q_pp.p95_sojourn_s);
        series_st.push(rate, q_st.p95_sojourn_s);
        dat.push_str(&format!(
            "{rate} {} {}\n",
            q_pp.p95_sojourn_s, q_st.p95_sojourn_s
        ));
        // STADI must dominate; the gap must exceed the raw service
        // gap once PP nears saturation.
        assert!(q_st.p95_sojourn_s <= q_pp.p95_sojourn_s + 1e-9);
        if rate * s_pp > 0.9 {
            assert!(
                gain / 100.0 > (1.0 - s_st / s_pp),
                "queueing should amplify the service-time gap"
            );
        }
    }
    table.print();
    println!("\np95 sojourn vs arrival rate:");
    print!("{}", render(&[series_pp, series_st], 60, 12));
    expt::save_results("ext_serving.dat", &dat)?;
    Ok(())
}
