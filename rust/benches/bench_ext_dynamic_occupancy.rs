//! EXTENSION: online adaptation to *drifting* occupancy.
//!
//! The paper measures rho_i once before inference ("the tensor size
//! remains fixed"). Real background jobs churn, so the profiler keeps
//! EWMAs of measured step times (§V "derived directly from historical
//! inference time profiles"). This bench simulates a background job
//! ramping 0% -> 60% on GPU1 over a request sequence and compares:
//!
//!   static  — plan from the initial measurement, never updated;
//!   adaptive — replan each request from the profiler's EWMA of the
//!              previous requests' (simulated) step timings.
//!
//! Expectation: adaptive tracks the drift (rows/steps shift over the
//! sequence) and the cumulative latency gap vs static widens as the
//! drift grows.
//!
//! Phase 2 (this PR): the per-request EWMA only helps the *next*
//! request — a background job landing mid-denoise still runs the
//! stale split to completion. The in-request ramp below injects a
//! deterministic occupancy step *inside* a request
//! (`serve::sim::simulate_drift_strategies`) and compares frozen vs
//! per-request-EWMA vs mid-flight re-planning (warmup-barrier +
//! every-K-syncs elastic re-splits), asserting the mid-flight
//! strategy strictly wins.

use stadi::config::{DeviceConfig, HaloMode};
use stadi::coordinator::timeline;
use stadi::device::build_cluster;
use stadi::expt;
use stadi::model::schedule::Schedule;
use stadi::runtime::ExecService;
use stadi::sched::plan::Plan;
use stadi::sched::Profiler;
use stadi::util::benchkit::Table;
use stadi::util::json::{self, Object, Value};
use stadi::util::plot::{render, Series};

fn main() -> stadi::Result<()> {
    if let Some(reason) = expt::skip_reason() {
        eprintln!("skipping: {reason}");
        return Ok(());
    }
    let svc = ExecService::spawn(expt::artifacts_dir())?;
    let model = svc.handle().manifest().model.clone();
    let schedule = Schedule::from_info(&svc.handle().manifest().schedule);
    let cost = expt::calibrated_cost(&svc)?;
    let comm = expt::paper_comm();
    let params = expt::paper_params();

    let n_requests = 12usize;
    // Occupancy ramp on GPU1: 0 -> 0.6 across the sequence.
    let occ_at = |k: usize| 0.6 * k as f64 / (n_requests - 1) as f64;

    let devices = vec![
        DeviceConfig::new("gpu0", 1.0, 0.0),
        DeviceConfig::new("gpu1", 1.0, 0.0),
    ];
    let mut profiler = Profiler::new(&devices);

    // Static plan from the clean initial state.
    let static_plan = Plan::build(
        &schedule,
        &[1.0, 1.0],
        &expt::names(2),
        &params,
        model.latent_h,
        model.row_granularity,
    )?;

    let mut table = Table::new(&[
        "req", "occ(gpu1)", "static (s)", "adaptive (s)",
        "adaptive plan", "est v1",
    ]);
    let mut cum_static = 0.0;
    let mut cum_adaptive = 0.0;
    let mut s_static = Series::new("static", 'o');
    let mut s_adapt = Series::new("adaptive", '#');
    let mut dat = String::new();
    let mut ramp = Vec::new();
    for k in 0..n_requests {
        let occ = occ_at(k);
        let cluster = build_cluster(
            &[
                DeviceConfig::new("gpu0", 1.0, 0.0),
                DeviceConfig::new("gpu1", 1.0, occ),
            ],
            cost,
        );

        // Adaptive plan from current profiler estimates.
        let speeds = profiler.effective_speeds();
        let adaptive_plan = Plan::build(
            &schedule,
            &speeds,
            &expt::names(2),
            &params,
            model.latent_h,
            model.row_granularity,
        )?;

        let t_static =
            timeline::simulate(&static_plan, &cluster, &comm, &model)?;
        let t_adaptive =
            timeline::simulate(&adaptive_plan, &cluster, &comm, &model)?;
        cum_static += t_static.total_s;
        cum_adaptive += t_adaptive.total_s;
        s_static.push(k as f64, t_static.total_s);
        s_adapt.push(k as f64, t_adaptive.total_s);

        // Feed the profiler what each device would have measured on
        // this request (per-step wall seconds under the true current
        // occupancy) — the paper's "historical inference time
        // profiles" loop.
        for d in adaptive_plan.included_devices() {
            let steps = d.steps.len();
            let secs =
                cluster[d.device].step_time(d.rows.rows) * steps as f64;
            profiler.record_step(d.device, d.rows.rows * steps, secs);
        }

        table.row(&[
            format!("{k}"),
            format!("{:.0}%", occ * 100.0),
            format!("{:.3}", t_static.total_s),
            format!("{:.3}", t_adaptive.total_s),
            format!(
                "{}:{} / {}+{} steps",
                adaptive_plan.devices[0].rows.rows,
                adaptive_plan.devices[1].rows.rows,
                adaptive_plan.devices[0].steps.len(),
                adaptive_plan.devices[1].steps.len(),
            ),
            format!("{:.2}", speeds[1]),
        ]);
        dat.push_str(&format!(
            "{k} {occ} {} {}\n",
            t_static.total_s, t_adaptive.total_s
        ));
        let mut e = Object::new();
        e.insert("req", Value::Num(k as f64));
        e.insert("occ_gpu1", Value::Num(occ));
        e.insert("static_s", Value::Num(t_static.total_s));
        e.insert("adaptive_s", Value::Num(t_adaptive.total_s));
        ramp.push(Value::Obj(e));
    }
    table.print();
    println!("\nper-request latency across the occupancy ramp:");
    print!("{}", render(&[s_static, s_adapt], 60, 12));
    println!(
        "cumulative: static {:.2}s vs adaptive {:.2}s ({:.1}% saved)",
        cum_static,
        cum_adaptive,
        (1.0 - cum_adaptive / cum_static) * 100.0
    );
    // Adaptation must win once the drift is under way (EWMA lags one
    // request by construction, so we don't require per-request wins).
    assert!(
        cum_adaptive < cum_static,
        "adaptive {cum_adaptive} should beat static {cum_static}"
    );
    expt::save_results("ext_dynamic_occupancy.dat", &dat)?;

    // ---- Phase 2: in-request ramp (mid-flight re-planning) ----------
    // A background job lands on GPU1 a third of the way into each
    // request's fast grid: the EWMA loop above cannot react until the
    // next request, the mid-flight re-planner fixes the tail of the
    // same request.
    let ramp_at = params.m_base / 3;
    let scenario = stadi::serve::sim::DriftScenario {
        requests: 4,
        drift: stadi::device::OccupancySchedule::parse(&format!(
            "0@0;0@0,0.6@{ramp_at}"
        ))?,
        replan: stadi::config::ReplanConfig {
            enabled: true,
            every_k_syncs: 4,
            drift_threshold: 0.1,
        },
    };
    let cmp = stadi::serve::sim::simulate_drift_strategies(
        &schedule,
        &params,
        &[
            DeviceConfig::new("gpu0", 1.0, 0.0),
            DeviceConfig::new("gpu1", 1.0, 0.0),
        ],
        cost,
        &comm,
        &model,
        &scenario,
    )?;
    let mut t2 = Table::new(&[
        "strategy", "total (s)", "req0", "req3", "replans", "migrated rows",
    ]);
    for (name, s) in [
        ("frozen", &cmp.frozen),
        ("per-request EWMA", &cmp.ewma),
        ("mid-flight", &cmp.midflight),
    ] {
        t2.row(&[
            name.to_string(),
            format!("{:.3}", s.total_s),
            format!("{:.3}", s.per_request_s[0]),
            format!("{:.3}", s.per_request_s[3]),
            format!("{}", s.replans),
            format!("{}", s.migrated_rows),
        ]);
    }
    println!("\nin-request occupancy ramp (0 -> 60% at fast step {ramp_at}):");
    t2.print();
    println!(
        "mid-flight saves {:.1}% vs frozen, {:.1}% vs EWMA-only",
        (1.0 - cmp.midflight.total_s / cmp.frozen.total_s) * 100.0,
        (1.0 - cmp.midflight.total_s / cmp.ewma.total_s) * 100.0
    );
    assert!(
        cmp.midflight.total_s < cmp.frozen.total_s,
        "mid-flight {} should strictly beat frozen {}",
        cmp.midflight.total_s,
        cmp.frozen.total_s
    );
    assert!(
        cmp.midflight.replans >= 1,
        "the ramp must trigger at least one in-request re-plan"
    );
    expt::save_results(
        "ext_dynamic_occupancy_midflight.json",
        &stadi::util::json::to_string_pretty(&cmp.to_json()),
    )?;

    // ---- Committed perf-trajectory artifact -------------------------
    // The ramp + mid-flight numbers plus the displaced-halo pricing of
    // the static plan at the most-drifted point of the ramp.
    let drifted = build_cluster(
        &[
            DeviceConfig::new("gpu0", 1.0, 0.0),
            DeviceConfig::new("gpu1", 1.0, occ_at(n_requests - 1)),
        ],
        cost,
    );
    let h_sync =
        timeline::simulate(&static_plan, &drifted, &comm, &model)?;
    let h_disp = timeline::simulate_with(
        &static_plan,
        &drifted,
        &comm,
        &model,
        HaloMode::Displaced { max_staleness: 1 },
    )?;
    assert!(
        h_disp.total_s <= h_sync.total_s + 1e-12,
        "displaced charging made the drifted plan slower"
    );
    let mut halo = Object::new();
    halo.insert("mode", Value::Str("displaced:1".into()));
    halo.insert("occ_gpu1", Value::Num(occ_at(n_requests - 1)));
    halo.insert("sync_total_s", Value::Num(h_sync.total_s));
    halo.insert("displaced_total_s", Value::Num(h_disp.total_s));
    halo.insert(
        "speedup_vs_sync",
        Value::Num(h_sync.total_s / h_disp.total_s),
    );
    let mut out = Object::new();
    out.insert("bench", Value::Str("dynamic_occupancy".into()));
    out.insert("cumulative_static_s", Value::Num(cum_static));
    out.insert("cumulative_adaptive_s", Value::Num(cum_adaptive));
    out.insert("ramp", Value::Arr(ramp));
    out.insert("midflight", cmp.to_json());
    out.insert("halo", Value::Obj(halo));
    expt::save_results(
        "BENCH_dynamic_occupancy.json",
        &json::to_string_pretty(&Value::Obj(out)),
    )?;
    Ok(())
}
