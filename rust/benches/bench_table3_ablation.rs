//! Table III reproduction: ablation of STADI's two mechanisms at
//! occupancies [0,20], [0,40], [0,60] on the 2-GPU testbed.
//!
//!   None    — patch parallelism (uniform patches, uniform steps)
//!   +SA     — spatial adaptation only
//!   +TA     — temporal adaptation only
//!   +TA+SA  — full STADI
//!
//! Paper values (shape to match): speedups over None grow with
//! imbalance — ~1.13/1.32/1.37x at [0,20] up to ~1.34/1.82/1.83x at
//! [0,60]; +TA dominates +SA under heavy imbalance; +TA+SA is best
//! everywhere.

use stadi::coordinator::timeline;
use stadi::expt;
use stadi::model::schedule::Schedule;
use stadi::runtime::ExecService;
use stadi::sched::plan::Plan;
use stadi::util::benchkit::Table;

fn main() -> stadi::Result<()> {
    if let Some(reason) = expt::skip_reason() {
        eprintln!("skipping: {reason}");
        return Ok(());
    }
    let svc = ExecService::spawn(expt::artifacts_dir())?;
    let model = svc.handle().manifest().model.clone();
    let schedule = Schedule::from_info(&svc.handle().manifest().schedule);
    let cost = expt::calibrated_cost(&svc)?;
    let comm = expt::paper_comm();

    let variants: [(&str, bool, bool); 4] = [
        ("None", false, false),
        ("+SA", false, true),
        ("+TA", true, false),
        ("+TA+SA", true, true),
    ];

    println!("# Table III — ablation (M_base=100, warmup=4)");
    let mut table = Table::new(&[
        "occupancy", "None(s)", "+SA", "+TA", "+TA+SA",
    ]);
    let mut dat = String::new();
    for occ in [[0.0, 0.2], [0.0, 0.4], [0.0, 0.6]] {
        let cluster = expt::cluster_with_occ(&occ, cost);
        let speeds = expt::speeds_for_occ(&occ);
        let mut lat = Vec::new();
        for (_, ta, sa) in variants {
            let mut params = expt::paper_params();
            params.temporal = ta;
            params.spatial = sa;
            // "None"/"+TA" use uniform patches; the plan builder does
            // that when spatial=false. "None" with uniform steps is
            // exactly DistriFusion.
            let plan = Plan::build(
                &schedule,
                &speeds,
                &expt::names(2),
                &params,
                model.latent_h,
                model.row_granularity,
            )?;
            let tl = timeline::simulate(&plan, &cluster, &comm, &model)?;
            lat.push(tl.total_s);
        }
        let base = lat[0];
        let fmt = |t: f64| format!("{t:.3} ({:.2}x)", base / t);
        table.row(&[
            format!("[{:.0}%,{:.0}%]", occ[0] * 100.0, occ[1] * 100.0),
            format!("{base:.3}"),
            fmt(lat[1]),
            fmt(lat[2]),
            fmt(lat[3]),
        ]);
        dat.push_str(&format!(
            "{} {} {} {} {} {}\n",
            occ[0], occ[1], lat[0], lat[1], lat[2], lat[3]
        ));

        // Shape assertions per the paper.
        assert!(lat[1] <= base && lat[3] <= base, "adaptations must help");
        assert!(
            lat[3] <= lat[1] + 1e-9 && lat[3] <= lat[2] + 1e-9,
            "+TA+SA must be the best"
        );
        if occ[1] >= 0.4 {
            assert!(
                lat[2] < lat[1],
                "+TA should beat +SA under heavy imbalance \
                 ({} vs {} at {occ:?})",
                lat[2],
                lat[1]
            );
        }
    }
    table.print();
    println!(
        "\npaper bands: 1.13/1.32/1.37x at [0,20] ... \
         1.34/1.82/1.83x at [0,60] (SA/TA/TA+SA over None)."
    );
    expt::save_results("table3_ablation.dat", &dat)?;
    Ok(())
}
