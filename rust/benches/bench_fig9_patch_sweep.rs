//! Fig. 9 reproduction: inference latency as a function of the patch
//! ratio, per occupancy setting, with the ratio STADI actually picks
//! marked.
//!
//! Paper setup: uniform steps (TA off — this figure isolates spatial
//! behaviour), patch rows of GPU0 swept 4..28 (GPU1 gets the rest),
//! occupancies [0,20], [0,40], [0,60]. Expectations (shape): each
//! curve is U-shaped with the optimum shifting toward larger GPU0
//! patches as GPU1's occupancy grows; the dashed 16:16 latency (pure
//! PP) sits above the optimum; STADI's chosen ratio lands at or next
//! to the minimum — except under extreme imbalance where the fixed
//! per-step overhead breaks linearity (the paper's own caveat).

use stadi::baselines::patch_parallel;
use stadi::coordinator::timeline;
use stadi::expt;
use stadi::model::schedule::Schedule;
use stadi::runtime::ExecService;
use stadi::sched::plan::Plan;
use stadi::util::benchkit::Table;

fn main() -> stadi::Result<()> {
    if let Some(reason) = expt::skip_reason() {
        eprintln!("skipping: {reason}");
        return Ok(());
    }
    let svc = ExecService::spawn(expt::artifacts_dir())?;
    let model = svc.handle().manifest().model.clone();
    let schedule = Schedule::from_info(&svc.handle().manifest().schedule);
    let cost = expt::calibrated_cost(&svc)?;
    let comm = expt::paper_comm();
    // TA off: Fig. 9 isolates the spatial axis.
    let mut params = expt::paper_params();
    params.temporal = false;

    let ratios: Vec<[usize; 2]> = (1..8).map(|g| [4 * g, 32 - 4 * g]).collect();

    println!(
        "# Fig. 9 — latency vs patch ratio (uniform steps, M={})",
        params.m_base
    );
    let mut dat = String::new();
    for occ in [[0.0, 0.2], [0.0, 0.4], [0.0, 0.6]] {
        let cluster = expt::cluster_with_occ(&occ, cost);
        let speeds = expt::speeds_for_occ(&occ);

        // STADI's spatial choice for this setting (SA only).
        let stadi_plan = Plan::build(
            &schedule,
            &speeds,
            &expt::names(2),
            &params,
            model.latent_h,
            model.row_granularity,
        )?;
        let chosen = stadi_plan.devices[0].rows.rows;

        let mut table = Table::new(&[
            "ratio g0:g1", "latency(s)", "marker",
        ]);
        let mut best = (0usize, f64::INFINITY);
        let mut lat = Vec::new();
        for r in &ratios {
            let plan =
                patch_parallel::plan_with_sizes(&schedule, r, &params)?;
            let tl = timeline::simulate(&plan, &cluster, &comm, &model)?;
            lat.push((r[0], tl.total_s));
            if tl.total_s < best.1 {
                best = (r[0], tl.total_s);
            }
        }
        for &(rows, t) in &lat {
            let mut marker = String::new();
            if rows == 16 {
                marker.push_str("-- pure PP");
            }
            if rows == chosen {
                marker.push_str(" ▲ STADI pick");
            }
            if rows == best.0 {
                marker.push_str(" (min)");
            }
            table.row(&[
                format!("{rows}:{}", 32 - rows),
                format!("{t:.3}"),
                marker,
            ]);
            dat.push_str(&format!(
                "{} {} {rows} {t}\n",
                occ[0], occ[1]
            ));
        }
        println!(
            "\n## occupancy [{:.0}%, {:.0}%] — STADI picks {chosen}:{}",
            occ[0] * 100.0,
            occ[1] * 100.0,
            32 - chosen
        );
        table.print();

        // Shape assertions. At mild/moderate imbalance the Eq. 5 pick
        // lands at (or next to) the sweep optimum. Under a heavy load
        // gap the paper itself observes the divergence we see here:
        // "patch allocation based on effective speed may not yield
        // optimal results, as the single-step delay no longer
        // maintains a linear relationship with the patch size due to
        // some fixed overhead" — so there we only require the pick to
        // strictly beat pure PP.
        let chosen_latency = lat
            .iter()
            .find(|&&(r, _)| r == chosen)
            .map(|&(_, t)| t)
            .unwrap_or_else(|| {
                // Chosen size off the 4-row sweep lattice (granularity
                // is 2): simulate it directly.
                let plan = patch_parallel::plan_with_sizes(
                    &schedule,
                    &[chosen, 32 - chosen],
                    &params,
                )
                .unwrap();
                timeline::simulate(&plan, &cluster, &comm, &model)
                    .unwrap()
                    .total_s
            });
        let pp_latency =
            lat.iter().find(|&&(r, _)| r == 16).unwrap().1;
        if occ[1] - occ[0] <= 0.41 {
            assert!(
                (chosen as i64 - best.0 as i64).unsigned_abs() <= 4,
                "STADI pick {chosen} far from sweep optimum {}",
                best.0
            );
        }
        assert!(
            chosen_latency < pp_latency,
            "STADI's ratio must beat pure PP: {chosen_latency} vs \
             {pp_latency}"
        );
    }
    expt::save_results("fig9_patch_sweep.dat", &dat)?;
    Ok(())
}
