//! Table II + Fig. 7 reproduction: image quality of Origin, patch
//! parallelism and STADI (three patch splits) at M_base ∈ {100, 50},
//! with REAL end-to-end generation through the AOT'd model.
//!
//! Substitutions (DESIGN.md §3): "ground truth" images are Origin
//! generations at disjoint seeds (standing in for COCO val images);
//! LPIPS/FID use the fixed random feature net ("-proxy"). What must
//! reproduce (shape, per the paper):
//!   * PSNR w/ G.T. ≈ flat low band for every method (unrelated
//!     images), differences < 0.1 dB-scale;
//!   * PSNR w/ Orig.: PP > STADI (step reduction costs fidelity),
//!     both far above the G.T. band;
//!   * FID-proxy w/ G.T.: method-to-method gap small (paper: < 1);
//!   * quality degrades slightly as M_base halves.
//!
//! Fig. 7 artifacts: per-config PGM mosaics under bench_out/fig7_*.pgm
//! and the per-split FID rows.

use stadi::baselines::{origin, patch_parallel};
use stadi::coordinator::dataflow;
use stadi::expt;
use stadi::metrics::{fid, lpips, psnr};
use stadi::model::latents::{seeded_cond, seeded_noise};
use stadi::model::schedule::Schedule;
use stadi::runtime::{ExecService, Tensor};
use stadi::sched::plan::Plan;
use stadi::util::benchkit::Table;
use stadi::util::stats;

const N_IMAGES: usize = 10;
const GT_SEED_BASE: u64 = 5000;

fn main() -> stadi::Result<()> {
    if let Some(reason) = expt::skip_reason() {
        eprintln!("skipping: {reason}");
        return Ok(());
    }
    let svc = ExecService::spawn(expt::artifacts_dir())?;
    let exec = svc.handle();
    let model = exec.manifest().model.clone();
    let schedule = Schedule::from_info(&exec.manifest().schedule);

    let run = |plan: &Plan, seed: u64| -> stadi::Result<Tensor> {
        let noise = seeded_noise(&model, seed);
        let cond = seeded_cond(&model, seed);
        Ok(dataflow::execute(&exec, plan, &noise, &cond)?.latent)
    };

    // "Ground truth" set: Origin generations at disjoint seeds
    // (COCO-val stand-in; full M=100 quality).
    let mut params_gt = expt::paper_params();
    params_gt.m_base = 100;
    let gt_plan = origin::plan(
        &schedule, &params_gt, model.latent_h, model.row_granularity,
    )?;
    eprintln!("generating {N_IMAGES} ground-truth images (Origin M=100)...");
    let gt_set: Vec<Tensor> = (0..N_IMAGES)
        .map(|i| run(&gt_plan, GT_SEED_BASE + i as u64))
        .collect::<stadi::Result<_>>()?;

    for m_base in [100usize, 50] {
        let mut params = expt::paper_params();
        params.m_base = m_base;
        println!("\n# Table II — M_base = {m_base} ({N_IMAGES} images)");

        // Method plans. STADI: device 1 in the Half band (speeds
        // [1.0, 0.5]) with the three forced splits of the paper.
        let origin_plan = origin::plan(
            &schedule, &params, model.latent_h, model.row_granularity,
        )?;
        let pp_plan = patch_parallel::plan(
            &schedule, 2, &params, model.latent_h, model.row_granularity,
        )?;
        let stadi_speeds = [1.0, 0.5];
        let splits: [[usize; 2]; 3] = [[24, 8], [16, 16], [8, 24]];

        let mut methods: Vec<(String, Plan)> = vec![
            ("Origin".into(), origin_plan.clone()),
            ("PatchPar 16:16".into(), pp_plan),
        ];
        for s in splits {
            methods.push((
                format!("STADI {}:{}", s[0], s[1]),
                Plan::build_with_sizes(
                    &schedule,
                    &stadi_speeds,
                    &expt::names(2),
                    &params,
                    &s,
                )?,
            ));
        }

        // Origin set for "w/ Orig." references (same seeds as methods).
        eprintln!("  generating Origin references...");
        let orig_set: Vec<Tensor> = (0..N_IMAGES)
            .map(|i| run(&origin_plan, i as u64))
            .collect::<stadi::Result<_>>()?;

        let mut table = Table::new(&[
            "method", "PSNR w/GT", "PSNR w/Orig", "LPIPSp w/GT",
            "LPIPSp w/Orig", "FIDp w/GT", "FIDp w/Orig",
        ]);
        let mut dat = String::new();
        for (name, plan) in &methods {
            eprintln!("  running {name}...");
            let set: Vec<Tensor> = (0..N_IMAGES)
                .map(|i| run(plan, i as u64))
                .collect::<stadi::Result<_>>()?;

            let mut p_gt = Vec::new();
            let mut p_or = Vec::new();
            let mut l_gt = Vec::new();
            let mut l_or = Vec::new();
            for i in 0..N_IMAGES {
                p_gt.push(psnr::psnr(&set[i], &gt_set[i]));
                l_gt.push(lpips::lpips(&exec, &set[i], &gt_set[i])?);
                if name != "Origin" {
                    p_or.push(psnr::psnr(&set[i], &orig_set[i]));
                    l_or.push(lpips::lpips(&exec, &set[i], &orig_set[i])?);
                }
            }
            let f_gt = fid::fid(&exec, &set, &gt_set)?;
            let f_or = if name == "Origin" {
                f64::NAN
            } else {
                fid::fid(&exec, &set, &orig_set)?
            };
            let fmt_opt = |v: &Vec<f64>, prec: usize| {
                if v.is_empty() {
                    "-".to_string()
                } else {
                    format!("{:.*}", prec, stats::mean(v))
                }
            };
            table.row(&[
                name.clone(),
                format!("{:.2}", stats::mean(&p_gt)),
                fmt_opt(&p_or, 2),
                format!("{:.3}", stats::mean(&l_gt)),
                fmt_opt(&l_or, 5),
                format!("{f_gt:.2}"),
                if f_or.is_nan() {
                    "-".into()
                } else {
                    format!("{f_or:.2}")
                },
            ]);
            dat.push_str(&format!(
                "{m_base} {name:?} {} {} {} {f_gt} {f_or}\n",
                stats::mean(&p_gt),
                fmt_opt(&p_or, 6),
                fmt_opt(&l_or, 8),
            ));

            // Fig. 7 visual artifact for the first image.
            let pgm = expt::latent_to_pgm(&set[0]);
            let fname = format!(
                "fig7_m{m_base}_{}.pgm",
                name.replace([' ', ':'], "_")
            );
            std::fs::create_dir_all("bench_out")?;
            std::fs::write(format!("bench_out/{fname}"), pgm)?;
        }
        table.print();
        expt::save_results(&format!("table2_m{m_base}.dat"), &dat)?;
    }
    println!(
        "\npaper shape: PSNR w/Orig: PP ≈ 24.7 > STADI ≈ 22-23; \
         PSNR w/GT flat ≈ 9.5 band; FID(GT) method gap < 1."
    );
    Ok(())
}
