//! EXTENSION: scaling beyond the paper's 2-GPU testbed (its stated
//! future work: "experiments with large-scale GPU clusters").
//!
//! Clusters of N ∈ {2, 3, 4, 6, 8} simulated GPUs with a mixed
//! occupancy profile; STADI vs patch parallelism latency and
//! utilization. Expectations: PP's latency is pinned to the worst
//! straggler regardless of N; STADI's advantage grows with cluster
//! heterogeneity; with N=8 on a 16-granule latent, spatial headroom
//! tightens (every device must keep ≥1 granule).

use stadi::baselines::patch_parallel;
use stadi::config::DeviceConfig;
use stadi::coordinator::timeline;
use stadi::device::build_cluster;
use stadi::expt;
use stadi::model::schedule::Schedule;
use stadi::runtime::ExecService;
use stadi::sched::plan::Plan;
use stadi::util::benchkit::Table;

/// Deterministic mixed occupancy profile: device i of n gets
/// rho_i = 0.6 * i / (n - 1) (fastest idle, slowest at 60%).
fn occupancies(n: usize) -> Vec<f64> {
    (0..n)
        .map(|i| if n == 1 { 0.0 } else { 0.6 * i as f64 / (n - 1) as f64 })
        .collect()
}

fn main() -> stadi::Result<()> {
    if let Some(reason) = expt::skip_reason() {
        eprintln!("skipping: {reason}");
        return Ok(());
    }
    let svc = ExecService::spawn(expt::artifacts_dir())?;
    let model = svc.handle().manifest().model.clone();
    let schedule = Schedule::from_info(&svc.handle().manifest().schedule);
    let cost = expt::calibrated_cost(&svc)?;
    let comm = expt::paper_comm();
    let params = expt::paper_params();

    println!(
        "# cluster scaling, mixed occupancy 0..60% (M_base={})",
        params.m_base
    );
    let mut table = Table::new(&[
        "N", "PP (s)", "PP util", "STADI (s)", "STADI util",
        "STADI vs PP", "classes",
    ]);
    let mut dat = String::new();
    for n in [2usize, 3, 4, 6, 8] {
        let occ = occupancies(n);
        let devices: Vec<DeviceConfig> = occ
            .iter()
            .enumerate()
            .map(|(i, &o)| DeviceConfig::new(format!("gpu{i}"), 1.0, o))
            .collect();
        let cluster = build_cluster(&devices, cost);
        let speeds = expt::speeds_for_occ(&occ);

        let pp = patch_parallel::plan(
            &schedule, n, &params, model.latent_h, model.row_granularity,
        )?;
        let t_pp = timeline::simulate(&pp, &cluster, &comm, &model)?;

        let stadi = Plan::build(
            &schedule,
            &speeds,
            &expt::names(n),
            &params,
            model.latent_h,
            model.row_granularity,
        )?;
        let t_st = timeline::simulate(&stadi, &cluster, &comm, &model)?;

        let classes: String = stadi
            .devices
            .iter()
            .map(|d| match d.class {
                stadi::sched::StepClass::Full => 'F',
                stadi::sched::StepClass::Half => 'H',
                stadi::sched::StepClass::Excluded => 'X',
            })
            .collect();
        table.row(&[
            format!("{n}"),
            format!("{:.3}", t_pp.total_s),
            format!("{:.0}%", t_pp.utilization * 100.0),
            format!("{:.3}", t_st.total_s),
            format!("{:.0}%", t_st.utilization * 100.0),
            format!("-{:.1}%", (1.0 - t_st.total_s / t_pp.total_s) * 100.0),
            classes,
        ]);
        dat.push_str(&format!("{n} {} {}\n", t_pp.total_s, t_st.total_s));

        assert!(t_st.total_s <= t_pp.total_s + 1e-9);
        assert!(t_st.utilization >= t_pp.utilization - 1e-9);
    }
    table.print();
    println!(
        "\nPP stays pinned to the 60% straggler at every N; STADI \
         reassigns steps (H) and rows instead."
    );
    expt::save_results("ext_scale.dat", &dat)?;
    Ok(())
}
