//! Hot-path microbenchmarks (EXPERIMENTS.md §Perf): where does a step
//! actually go, layer by layer?
//!
//!   L1/L2 — PJRT denoiser execution per patch height (the compute),
//!           DDIM update rust-native vs AOT'd artifact;
//!   L3    — exec-service RPC overhead, buffer scatter/gather,
//!           dataflow-executor non-compute overhead, collective bus
//!           throughput, uneven-gather cost strategies, timeline
//!           simulator throughput.

use std::time::Instant;

use stadi::comm::{all_gather_cost, CollectiveBus};
use stadi::config::{CommConfig, UnevenStrategy};
use stadi::coordinator::{dataflow, timeline};
use stadi::expt;
use stadi::model::sampler;
use stadi::model::schedule::{DdimCoef, Schedule};
use stadi::model::latents::{seeded_cond, seeded_noise};
use stadi::runtime::{ExecService, Tensor};
use stadi::sched::plan::Plan;
use stadi::util::benchkit::{self, banner, fmt_secs, Table};
use stadi::util::rng::NormalGen;

fn main() -> stadi::Result<()> {
    if let Some(reason) = expt::skip_reason() {
        eprintln!("skipping: {reason}");
        return Ok(());
    }
    let svc = ExecService::spawn(expt::artifacts_dir())?;
    let exec = svc.handle();
    let model = exec.manifest().model.clone();
    let schedule = Schedule::from_info(&exec.manifest().schedule);

    // ------------------------------------------------ L1/L2: compute
    banner("denoiser execution per patch height (PJRT, via service)");
    let mut t = Table::new(&["h rows", "tokens", "median", "µs/row"]);
    let kv = Tensor::zeros(&model.kv_shape());
    let cond = vec![0.1f32; model.dim];
    for &h in &exec.manifest().patch_heights.clone() {
        let x = Tensor::zeros(&[h, model.latent_w, model.latent_c]);
        let s = benchkit::bench(format!("h{h}"), 2, 7, || {
            exec.denoise(h, &x, &kv, 0, 500.0, &cond).unwrap();
        });
        t.row(&[
            format!("{h}"),
            format!("{}", model.tokens_for_rows(h)),
            fmt_secs(s.p50_s),
            format!("{:.1}", s.p50_s * 1e6 / h as f64),
        ]);
    }
    t.print();

    banner("DDIM update: rust-native vs AOT artifact (full latent)");
    let mut g = NormalGen::new(1);
    let n: usize = model.latent_shape().iter().product();
    let x = Tensor::new(model.latent_shape(), g.vec_f32(n))?;
    let eps = Tensor::new(model.latent_shape(), g.vec_f32(n))?;
    let coef = DdimCoef { coef_x: 0.98, coef_eps: -0.1 };
    let s_native = benchkit::bench("native", 3, 50, || {
        let mut xx = x.clone();
        sampler::ddim_update_inplace(&mut xx, &eps, coef);
        std::hint::black_box(&xx);
    });
    let s_art = benchkit::bench("artifact", 2, 10, || {
        exec.ddim_artifact(&x, &eps, 0.98, -0.1).unwrap();
    });
    println!(
        "native {} vs artifact {} ({}x — native wins on dispatch \
         overhead; kept native on the hot path)",
        fmt_secs(s_native.p50_s),
        fmt_secs(s_art.p50_s),
        (s_art.p50_s / s_native.p50_s).round()
    );

    // ------------------------------------------------ L3: service RPC
    banner("exec-service RPC + tensor-copy overhead");
    // Compare a h=4 denoise (small compute) against pure message cost
    // approximated by the same call repeated — measured delta between
    // service call and in-thread compute is the copy+channel overhead;
    // here we report the call as an upper bound.
    let x4 = Tensor::zeros(&[4, model.latent_w, model.latent_c]);
    let s_rpc = benchkit::bench("h4 via service", 2, 10, || {
        exec.denoise(4, &x4, &kv, 0, 500.0, &cond).unwrap();
    });
    println!(
        "smallest-step service round-trip: {} (includes ~{}KB of \
         input copies)",
        fmt_secs(s_rpc.p50_s),
        (kv.byte_len() + x4.byte_len()) / 1024
    );

    // ------------------------------------------- L3: dataflow overhead
    banner("dataflow executor non-compute overhead");
    let params = stadi::config::StadiParams {
        m_base: 10,
        m_warmup: 2,
        ..Default::default()
    };
    let plan = Plan::build(
        &schedule,
        &[1.0, 0.5],
        &expt::names(2),
        &params,
        model.latent_h,
        model.row_granularity,
    )?;
    let noise = seeded_noise(&model, 1);
    let cnd = seeded_cond(&model, 1);
    let t0 = Instant::now();
    let out = dataflow::execute(&exec, &plan, &noise, &cnd)?;
    let wall = t0.elapsed().as_secs_f64();
    let compute: f64 = out.stats.compute_s.iter().sum();
    println!(
        "wall {} vs compute {} -> coordinator overhead {:.1}%",
        fmt_secs(wall),
        fmt_secs(compute),
        (wall - compute) / wall * 100.0
    );

    // ------------------------------------------------ L3: comm bus
    banner("collective bus: 2-thread uneven all-gather throughput");
    let bus = CollectiveBus::new();
    let iters = 200;
    let payload_len = 16 * model.latent_w * model.latent_c
        + model.layers * 128 * 2 * model.dim;
    let t0 = Instant::now();
    let b2 = bus.clone();
    let h = std::thread::spawn(move || {
        for _ in 0..iters {
            b2.all_gather("bench", 1, &[0, 1], vec![1.0; payload_len])
                .unwrap();
        }
    });
    for _ in 0..iters {
        bus.all_gather("bench", 0, &[0, 1], vec![0.0; payload_len])
            .unwrap();
    }
    h.join().unwrap();
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!(
        "{} per barrier ({} KB payload/rank)",
        fmt_secs(per),
        payload_len * 4 / 1024
    );

    banner("uneven all-gather cost model: pad vs multi-broadcast");
    let mut t = Table::new(&["sizes (KB)", "pad", "broadcast"]);
    for sizes in [[128usize, 128], [192, 64], [240, 16]] {
        let bytes: Vec<usize> = sizes.iter().map(|s| s * 1024).collect();
        let pad = all_gather_cost(
            &CommConfig {
                uneven_strategy: UnevenStrategy::PadAllGather,
                ..Default::default()
            },
            &bytes,
        );
        let bc = all_gather_cost(
            &CommConfig {
                uneven_strategy: UnevenStrategy::MultiBroadcast,
                ..Default::default()
            },
            &bytes,
        );
        t.row(&[
            format!("{}:{}", sizes[0], sizes[1]),
            fmt_secs(pad),
            fmt_secs(bc),
        ]);
    }
    t.print();

    // --------------------------------------------- timeline sim speed
    banner("timeline simulator throughput");
    let cost = expt::calibrated_cost(&svc)?;
    let cluster = expt::cluster_with_occ(&[0.0, 0.4], cost);
    let comm = expt::paper_comm();
    let big_plan = Plan::build(
        &schedule,
        &[1.0, 0.5],
        &expt::names(2),
        &expt::paper_params(),
        model.latent_h,
        model.row_granularity,
    )?;
    let s = benchkit::bench("sim", 10, 200, || {
        timeline::simulate(&big_plan, &cluster, &comm, &model).unwrap();
    });
    println!(
        "{} per 100-step plan simulation ({:.0} plans/s)",
        fmt_secs(s.p50_s),
        1.0 / s.p50_s
    );

    Ok(())
}
