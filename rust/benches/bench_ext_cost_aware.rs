//! EXTENSION ablation: Eq. 5 patch mending vs cost-aware mending.
//!
//! The paper's Fig. 9 discussion concedes that "patch allocation based
//! on effective speed may not yield optimal results" under large load
//! gaps because of the fixed per-step overhead. This bench quantifies
//! how much the affine-cost allocator (`spatial::cost_aware_sizes`)
//! recovers, sweeping occupancy gaps on the 2-GPU testbed with TA both
//! off (isolating the spatial axis) and on (full STADI).

use stadi::coordinator::timeline;
use stadi::expt;
use stadi::model::schedule::Schedule;
use stadi::runtime::ExecService;
use stadi::sched::plan::Plan;
use stadi::util::benchkit::Table;
use stadi::util::plot::{render, Series};

fn main() -> stadi::Result<()> {
    if let Some(reason) = expt::skip_reason() {
        eprintln!("skipping: {reason}");
        return Ok(());
    }
    let svc = ExecService::spawn(expt::artifacts_dir())?;
    let model = svc.handle().manifest().model.clone();
    let schedule = Schedule::from_info(&svc.handle().manifest().schedule);
    let cost = expt::calibrated_cost(&svc)?;
    let comm = expt::paper_comm();

    for ta in [false, true] {
        let mut params = expt::paper_params();
        params.temporal = ta;
        println!(
            "\n# cost-aware vs Eq. 5 patch mending (TA {})",
            if ta { "on — full STADI" } else { "off — spatial only" }
        );
        let mut table = Table::new(&[
            "occupancy", "Eq.5 rows", "Eq.5 (s)", "cost-aware rows",
            "cost-aware (s)", "gain",
        ]);
        let mut s_eq5 = Series::new("eq5", 'o');
        let mut s_ca = Series::new("cost-aware", '#');
        let mut dat = String::new();
        for occ1 in [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7] {
            let occ = [0.0, occ1];
            let cluster = expt::cluster_with_occ(&occ, cost);
            let speeds = expt::speeds_for_occ(&occ);

            let p_eq5 = Plan::build(
                &schedule,
                &speeds,
                &expt::names(2),
                &params,
                model.latent_h,
                model.row_granularity,
            )?;
            let t_eq5 =
                timeline::simulate(&p_eq5, &cluster, &comm, &model)?;

            let p_ca = Plan::build_cost_aware(
                &schedule,
                &speeds,
                &expt::names(2),
                &params,
                &cost,
                model.latent_h,
                model.row_granularity,
            )?;
            let t_ca = timeline::simulate(&p_ca, &cluster, &comm, &model)?;

            let gain = (1.0 - t_ca.total_s / t_eq5.total_s) * 100.0;
            table.row(&[
                format!("[0%,{:.0}%]", occ1 * 100.0),
                format!(
                    "{}:{}",
                    p_eq5.devices[0].rows.rows, p_eq5.devices[1].rows.rows
                ),
                format!("{:.3}", t_eq5.total_s),
                format!(
                    "{}:{}",
                    p_ca.devices[0].rows.rows, p_ca.devices[1].rows.rows
                ),
                format!("{:.3}", t_ca.total_s),
                format!("{gain:+.1}%"),
            ]);
            s_eq5.push(occ1, t_eq5.total_s);
            s_ca.push(occ1, t_ca.total_s);
            dat.push_str(&format!(
                "{ta} {occ1} {} {}\n",
                t_eq5.total_s, t_ca.total_s
            ));

            // The extension must never lose to Eq. 5 (it optimizes the
            // same objective with a strictly better cost model).
            assert!(
                t_ca.total_s <= t_eq5.total_s + 1e-9,
                "cost-aware lost at occ {occ1}: {} vs {}",
                t_ca.total_s,
                t_eq5.total_s
            );
        }
        table.print();
        println!("\nlatency vs straggler occupancy:");
        print!("{}", render(&[s_eq5, s_ca], 60, 12));
        expt::save_results(
            &format!("ext_cost_aware_ta{ta}.dat"),
            &dat,
        )?;
    }
    Ok(())
}
