//! Wire-protocol hot path: what does one request line cost before and
//! after the engine does any real work?
//!
//!   parse    — v2 (`"spec"` object) and v1 (bare `"seed"`) request
//!              lines through `WireRequest::parse` and the lazy
//!              scanner (`parse_lazy`), on the canonical lines the
//!              committed `BENCH_protocol.json` models;
//!   format   — request re-serialization, success responses
//!              (`response_line`, which embeds the per-device plan and
//!              a latent summary), and error/busy lines.
//!
//! Std-only: runs on every build — it writes its own stub artifact
//! set and executes one request on the stub runtime to get a real
//! `Generation` for the response path. Results land in
//! `bench_out/BENCH_protocol.json` (measured wall clock, not part of
//! the committed repo-root artifacts). The committed repo-root
//! `BENCH_protocol.json` carries the deterministic parse cost model
//! from `scripts/gen_bench_artifacts.py`; this bench recomputes the
//! same model inline, asserts the modeled v2 lazy speedup stays >= 5x,
//! and cross-checks it against measured wall clock (warn-only: wall
//! clock is machine- and load-dependent).

use stadi::config::{EngineConfig, StadiParams};
use stadi::coordinator::EngineCore;
use stadi::error::Error;
use stadi::expt;
use stadi::runtime::stubgen;
use stadi::serve::protocol::{
    busy_line, error_line, parse_lazy, parse_lazy_tracked,
    response_line, WireRequest,
};
use stadi::spec::GenerationSpec;
use stadi::util::benchkit::{self, banner, fmt_secs, Table};
use stadi::util::json::{self, Object, Value};

// --- parse cost model (scripts/gen_bench_artifacts.py mirror) --------
// Relative per-operation costs of the two parse paths, in abstract
// units: the full tree parse scans every byte, allocates a Value node
// per JSON value, pushes a key entry per object member, and copies
// every string (keys and values) into the tree; the lazy scanner
// walks every byte in place, pays a constant dispatch cost per field,
// and materializes exactly one string — the request id. Keep the
// constants and the canonical lines byte-identical to the script.
const SCAN_PER_BYTE: usize = 1;
const TREE_NODE: usize = 60;
const TREE_KEY: usize = 40;
const STRING_COPY_PER_BYTE: usize = 2;
const LAZY_FIELD: usize = 6;

const V2_LINE: &str = concat!(
    r#"{"id":"req-000123","spec":{"seed":123456789,"steps":28,"#,
    r#""height":256,"width":256,"quality":"standard","#,
    r#""priority":"normal","deadline_s":2.5}}"#
);
const V1_LINE: &str = r#"{"id":"req-000123","seed":123456789}"#;

/// `(value nodes, object keys, copied string bytes)` of the line's
/// JSON tree — the quantities the cost model weighs.
fn tree_counts(line: &str) -> (usize, usize, usize) {
    fn walk(
        v: &Value,
        nodes: &mut usize,
        keys: &mut usize,
        sbytes: &mut usize,
    ) {
        *nodes += 1;
        match v {
            Value::Obj(o) => {
                for (k, val) in o.iter() {
                    *keys += 1;
                    *sbytes += k.len();
                    walk(val, nodes, keys, sbytes);
                }
            }
            Value::Arr(a) => {
                for val in a {
                    walk(val, nodes, keys, sbytes);
                }
            }
            Value::Str(s) => *sbytes += s.len(),
            _ => {}
        }
    }
    let v = json::parse(line).expect("canonical line parses");
    let (mut nodes, mut keys, mut sbytes) = (0, 0, 0);
    walk(&v, &mut nodes, &mut keys, &mut sbytes);
    (nodes, keys, sbytes)
}

/// Modeled `(full, lazy)` cost in abstract units.
fn modeled_costs(line: &str, id_bytes: usize) -> (usize, usize) {
    let (nodes, keys, sbytes) = tree_counts(line);
    let full = line.len() * SCAN_PER_BYTE
        + nodes * TREE_NODE
        + keys * TREE_KEY
        + sbytes * STRING_COPY_PER_BYTE;
    // The scanner visits each key once and copies only the id.
    let lazy = line.len() * SCAN_PER_BYTE
        + keys * LAZY_FIELD
        + id_bytes * STRING_COPY_PER_BYTE;
    (full, lazy)
}

fn main() -> stadi::Result<()> {
    let dir = std::env::temp_dir()
        .join(format!("stadi-bench-protocol-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    stubgen::write_stub_artifacts(
        &dir,
        stubgen::DEFAULT_EXTRA_RESOLUTIONS,
    )?;
    let mut cfg = EngineConfig::two_gpu_default(&dir, &[0.0, 0.4]);
    cfg.stadi =
        StadiParams { m_base: 6, m_warmup: 2, ..Default::default() };
    let core = EngineCore::new(cfg)?;

    let spec = GenerationSpec::new().seed(7);
    let generation = core.session_for(&spec)?.execute(&spec)?;
    let req = WireRequest { id: "bench-1".into(), spec: spec.clone() };
    let v2 = V2_LINE.to_string();
    let v1 = V1_LINE.to_string();

    // The canonical lines must take the scanner's fast path and agree
    // with the full parse — otherwise the lazy numbers below measure
    // the fallback, not the hot path.
    for line in [V2_LINE, V1_LINE] {
        let (lazy_res, fast) = parse_lazy_tracked(line);
        assert!(fast, "canonical line fell off the fast path: {line}");
        assert_eq!(
            lazy_res.unwrap().to_line(),
            WireRequest::parse(line).unwrap().to_line(),
            "lazy/full divergence on {line}"
        );
    }

    banner("request parsing (per line)");
    let s_parse_v2 = benchkit::bench("parse v2", 3, 2000, || {
        std::hint::black_box(WireRequest::parse(&v2).unwrap());
    });
    let s_parse_v1 = benchkit::bench("parse v1", 3, 2000, || {
        std::hint::black_box(WireRequest::parse(&v1).unwrap());
    });
    let s_lazy_v2 = benchkit::bench("parse_lazy v2", 3, 2000, || {
        std::hint::black_box(parse_lazy(&v2).unwrap());
    });
    let s_lazy_v1 = benchkit::bench("parse_lazy v1", 3, 2000, || {
        std::hint::black_box(parse_lazy(&v1).unwrap());
    });

    // Deterministic cost model (the committed-artifact criterion) and
    // the measured cross-check. The id is 10 bytes in both lines.
    let (full_v2, lazy_v2_cost) = modeled_costs(V2_LINE, 10);
    let (full_v1, lazy_v1_cost) = modeled_costs(V1_LINE, 10);
    let modeled_v2 = full_v2 as f64 / lazy_v2_cost as f64;
    let modeled_v1 = full_v1 as f64 / lazy_v1_cost as f64;
    assert!(
        modeled_v2 >= 5.0,
        "modeled v2 lazy speedup {modeled_v2:.2}x fell below the 5x \
         committed-artifact criterion"
    );
    let measured_v2 = s_parse_v2.p50_s / s_lazy_v2.p50_s;
    println!(
        "lazy vs full (v2): modeled {modeled_v2:.2}x, measured \
         {measured_v2:.2}x; (v1): modeled {modeled_v1:.2}x"
    );
    if measured_v2 < 5.0 {
        println!(
            "warning: measured v2 lazy speedup {measured_v2:.2}x \
             below the modeled gate (wall clock is machine- and \
             load-dependent; the committed artifact gates the model)"
        );
    }

    banner("response formatting (per line)");
    let s_req = benchkit::bench("request to_line", 3, 2000, || {
        std::hint::black_box(req.to_line());
    });
    let s_resp = benchkit::bench("response_line", 3, 2000, || {
        std::hint::black_box(response_line(
            "bench-1",
            &spec,
            &generation,
            0.1,
        ));
    });
    let err = Error::Protocol("spec rejected".into());
    let s_err = benchkit::bench("error_line", 3, 2000, || {
        std::hint::black_box(error_line("bench-1", &err));
    });
    let s_busy = benchkit::bench("busy_line", 3, 2000, || {
        std::hint::black_box(busy_line("bench-1", 17));
    });

    let mut t = Table::new(&["op", "median", "line bytes"]);
    for (name, s, bytes) in [
        ("parse v2", &s_parse_v2, v2.len()),
        ("parse v1", &s_parse_v1, v1.len()),
        ("parse_lazy v2", &s_lazy_v2, v2.len()),
        ("parse_lazy v1", &s_lazy_v1, v1.len()),
        ("request to_line", &s_req, v2.len()),
        (
            "response_line",
            &s_resp,
            response_line("bench-1", &spec, &generation, 0.1).len(),
        ),
        ("error_line", &s_err, error_line("bench-1", &err).len()),
        ("busy_line", &s_busy, busy_line("bench-1", 17).len()),
    ] {
        t.row(&[
            name.to_string(),
            fmt_secs(s.p50_s),
            format!("{bytes}"),
        ]);
    }
    t.print();

    let mut o = Object::new();
    o.insert("bench", Value::Str("protocol".into()));
    o.insert(
        "source",
        Value::Str(
            "benches/bench_protocol.rs — measured wall clock on the \
             stub runtime (not a committed artifact)"
                .into(),
        ),
    );
    o.insert("halo", Value::Str("none (wire protocol only)".into()));
    let mut ops = Object::new();
    for (name, s) in [
        ("parse_v2_s", &s_parse_v2),
        ("parse_v1_s", &s_parse_v1),
        ("parse_lazy_v2_s", &s_lazy_v2),
        ("parse_lazy_v1_s", &s_lazy_v1),
        ("request_to_line_s", &s_req),
        ("response_line_s", &s_resp),
        ("error_line_s", &s_err),
        ("busy_line_s", &s_busy),
    ] {
        ops.insert(name, Value::Num(s.p50_s));
    }
    o.insert("median", Value::Obj(ops));
    let mut lazy = Object::new();
    lazy.insert("modeled_speedup_v2", Value::Num(modeled_v2));
    lazy.insert("modeled_speedup_v1", Value::Num(modeled_v1));
    lazy.insert("measured_speedup_v2", Value::Num(measured_v2));
    lazy.insert(
        "measured_speedup_v1",
        Value::Num(s_parse_v1.p50_s / s_lazy_v1.p50_s),
    );
    o.insert("lazy_vs_full", Value::Obj(lazy));
    expt::save_results(
        "BENCH_protocol.json",
        &stadi::util::json::to_string_pretty(&Value::Obj(o)),
    )?;
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
