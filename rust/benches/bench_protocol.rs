//! Wire-protocol hot path: what does one request line cost before and
//! after the engine does any real work?
//!
//!   parse    — v2 (`"spec"` object) and v1 (bare `"seed"`) request
//!              lines through `WireRequest::parse`;
//!   format   — request re-serialization, success responses
//!              (`response_line`, which embeds the per-device plan and
//!              a latent summary), and error/busy lines.
//!
//! Std-only: runs on every build — it writes its own stub artifact
//! set and executes one request on the stub runtime to get a real
//! `Generation` for the response path. Results land in
//! `bench_out/BENCH_protocol.json` (measured wall clock, not part of
//! the committed repo-root artifacts).

use stadi::config::{EngineConfig, StadiParams};
use stadi::coordinator::EngineCore;
use stadi::error::Error;
use stadi::expt;
use stadi::runtime::stubgen;
use stadi::serve::protocol::{
    busy_line, error_line, response_line, WireRequest,
};
use stadi::spec::GenerationSpec;
use stadi::util::benchkit::{self, banner, fmt_secs, Table};
use stadi::util::json::{Object, Value};

fn main() -> stadi::Result<()> {
    let dir = std::env::temp_dir()
        .join(format!("stadi-bench-protocol-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    stubgen::write_stub_artifacts(
        &dir,
        stubgen::DEFAULT_EXTRA_RESOLUTIONS,
    )?;
    let mut cfg = EngineConfig::two_gpu_default(&dir, &[0.0, 0.4]);
    cfg.stadi =
        StadiParams { m_base: 6, m_warmup: 2, ..Default::default() };
    let core = EngineCore::new(cfg)?;

    let spec = GenerationSpec::new().seed(7);
    let generation = core.session_for(&spec)?.execute(&spec)?;
    let req = WireRequest { id: "bench-1".into(), spec: spec.clone() };
    let v2 = req.to_line();
    let v1 = req.to_line_v1();

    banner("request parsing (per line)");
    let s_parse_v2 = benchkit::bench("parse v2", 3, 2000, || {
        std::hint::black_box(WireRequest::parse(&v2).unwrap());
    });
    let s_parse_v1 = benchkit::bench("parse v1", 3, 2000, || {
        std::hint::black_box(WireRequest::parse(&v1).unwrap());
    });

    banner("response formatting (per line)");
    let s_req = benchkit::bench("request to_line", 3, 2000, || {
        std::hint::black_box(req.to_line());
    });
    let s_resp = benchkit::bench("response_line", 3, 2000, || {
        std::hint::black_box(response_line(
            "bench-1",
            &spec,
            &generation,
            0.1,
        ));
    });
    let err = Error::Protocol("spec rejected".into());
    let s_err = benchkit::bench("error_line", 3, 2000, || {
        std::hint::black_box(error_line("bench-1", &err));
    });
    let s_busy = benchkit::bench("busy_line", 3, 2000, || {
        std::hint::black_box(busy_line("bench-1", 17));
    });

    let mut t = Table::new(&["op", "median", "line bytes"]);
    for (name, s, bytes) in [
        ("parse v2", &s_parse_v2, v2.len()),
        ("parse v1", &s_parse_v1, v1.len()),
        ("request to_line", &s_req, v2.len()),
        (
            "response_line",
            &s_resp,
            response_line("bench-1", &spec, &generation, 0.1).len(),
        ),
        ("error_line", &s_err, error_line("bench-1", &err).len()),
        ("busy_line", &s_busy, busy_line("bench-1", 17).len()),
    ] {
        t.row(&[
            name.to_string(),
            fmt_secs(s.p50_s),
            format!("{bytes}"),
        ]);
    }
    t.print();

    let mut o = Object::new();
    o.insert("bench", Value::Str("protocol".into()));
    o.insert(
        "source",
        Value::Str(
            "benches/bench_protocol.rs — measured wall clock on the \
             stub runtime (not a committed artifact)"
                .into(),
        ),
    );
    o.insert("halo", Value::Str("none (wire protocol only)".into()));
    let mut ops = Object::new();
    for (name, s) in [
        ("parse_v2_s", &s_parse_v2),
        ("parse_v1_s", &s_parse_v1),
        ("request_to_line_s", &s_req),
        ("response_line_s", &s_resp),
        ("error_line_s", &s_err),
        ("busy_line_s", &s_busy),
    ] {
        ops.insert(name, Value::Num(s.p50_s));
    }
    o.insert("median", Value::Obj(ops));
    expt::save_results(
        "BENCH_protocol.json",
        &stadi::util::json::to_string_pretty(&Value::Obj(o)),
    )?;
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
