//! Fig. 8 reproduction: end-to-end latency of tensor parallelism,
//! patch parallelism (DistriFusion) and STADI under the paper's two
//! occupancy scenario families on the 2-GPU testbed:
//!
//!   (a) decreasing total resources: [0,20], [0,40], [0,60]
//!   (b) fixed total (80%), redistributed: [35,45], [30,50], [25,55]
//!
//! Paper headline: STADI cuts latency vs patch parallelism by
//! 12-45% in (a) and 4-39% in (b); tensor parallelism is slowest
//! everywhere. We check the *shape*: ordering, growing gap with
//! asymmetry, and the no-TA-trigger cases ([0,20], [35,45]) where
//! only patch mending helps.

use stadi::baselines::{patch_parallel, tensor_parallel};
use stadi::coordinator::timeline;
use stadi::expt;
use stadi::model::schedule::Schedule;
use stadi::runtime::ExecService;
use stadi::sched::plan::Plan;
use stadi::util::benchkit::Table;

fn main() -> stadi::Result<()> {
    if let Some(reason) = expt::skip_reason() {
        eprintln!("skipping: {reason}");
        return Ok(());
    }
    let svc = ExecService::spawn(expt::artifacts_dir())?;
    let model = svc.handle().manifest().model.clone();
    let schedule = Schedule::from_info(&svc.handle().manifest().schedule);
    let cost = expt::calibrated_cost(&svc)?;
    let params = expt::paper_params();
    let comm = expt::paper_comm();

    let scenarios: [(&str, [[f64; 2]; 3]); 2] = [
        ("(a) decreasing total", [[0.0, 0.2], [0.0, 0.4], [0.0, 0.6]]),
        ("(b) fixed total 80%", [[0.35, 0.45], [0.3, 0.5], [0.25, 0.55]]),
    ];

    let pp_plan = patch_parallel::plan(
        &schedule, 2, &params, model.latent_h, model.row_granularity,
    )?;

    let mut dat = String::new();
    for (name, occs) in scenarios {
        println!("\n# Fig. 8{name}  (M_base={})", params.m_base);
        let mut table = Table::new(&[
            "occupancy", "TP(s)", "PP(s)", "STADI(s)", "STADI vs PP",
            "TA triggered",
        ]);
        for occ in occs {
            let cluster = expt::cluster_with_occ(&occ, cost);
            let speeds = expt::speeds_for_occ(&occ);

            let t_tp = tensor_parallel::latency(
                params.m_base, &cluster, &comm, &model,
            );
            let t_pp =
                timeline::simulate(&pp_plan, &cluster, &comm, &model)?;
            let stadi_plan = Plan::build(
                &schedule,
                &speeds,
                &expt::names(2),
                &params,
                model.latent_h,
                model.row_granularity,
            )?;
            let t_st =
                timeline::simulate(&stadi_plan, &cluster, &comm, &model)?;
            let ta = stadi_plan.devices[1].steps.len()
                != stadi_plan.devices[0].steps.len();
            let reduction =
                (1.0 - t_st.total_s / t_pp.total_s) * 100.0;
            table.row(&[
                format!("[{:.0}%,{:.0}%]", occ[0] * 100.0, occ[1] * 100.0),
                format!("{:.3}", t_tp.total_s),
                format!("{:.3}", t_pp.total_s),
                format!("{:.3}", t_st.total_s),
                format!("-{reduction:.1}%"),
                format!("{ta}"),
            ]);
            dat.push_str(&format!(
                "{} {} {} {} {}\n",
                occ[0], occ[1], t_tp.total_s, t_pp.total_s, t_st.total_s
            ));

            // Shape assertions (paper ordering; near-ties allowed at
            // mild heterogeneity where both degenerate to the same
            // straggler bound).
            assert!(
                t_tp.total_s > 0.98 * t_pp.total_s,
                "TP should be slowest: {} vs {}",
                t_tp.total_s,
                t_pp.total_s
            );
            assert!(
                t_st.total_s <= t_pp.total_s + 1e-9,
                "STADI should not lose to PP"
            );
        }
        table.print();
    }
    println!(
        "\npaper bands: (a) 12-45% reduction vs PP, (b) 4-39%; \
         TA does not trigger at [0,20] / [35,45] (v1 > a*v0)."
    );
    expt::save_results("fig8_latency.dat", &dat)?;
    Ok(())
}
