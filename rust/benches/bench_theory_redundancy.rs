//! Theorems 1-2 empirical validation: temporal redundancy is O(1/M).
//!
//! Thm 1 (DistriFusion): the per-step state difference |x_{t_m} -
//! x_{t_{m+1}}| of a DDIM trajectory is bounded by C·T/M. We run real
//! sequential trajectories for a sweep of M and fit log-log slope of
//! mean per-step drift vs M — expect ≈ -1.
//!
//! Thm 2 (STADI): two devices running grids with 2:1 step counts stay
//! O(1/M)-consistent at aligned timesteps. We run the fast grid and
//! the STADI slow grid (same model, same seed) and measure the state
//! difference at every common timestep — again expect slope ≈ -1 in M.

use stadi::expt;
use stadi::model::sampler;
use stadi::model::latents::{seeded_cond, seeded_noise};
use stadi::model::schedule::Schedule;
use stadi::runtime::{ExecService, Tensor};
use stadi::util::benchkit::Table;
use stadi::util::stats;

fn main() -> stadi::Result<()> {
    if let Some(reason) = expt::skip_reason() {
        eprintln!("skipping: {reason}");
        return Ok(());
    }
    let svc = ExecService::spawn(expt::artifacts_dir())?;
    let exec = svc.handle();
    let model = exec.manifest().model.clone();
    let schedule = Schedule::from_info(&exec.manifest().schedule);
    let h = model.latent_h;

    // Sequential full-image trajectory over a grid; returns states
    // after each step, keyed by post timestep.
    let mut run_grid = |grid: &[usize], seed: u64| -> stadi::Result<Vec<(Option<usize>, Tensor)>> {
        let mut x = seeded_noise(&model, seed);
        let cond = seeded_cond(&model, seed);
        let mut kv = Tensor::zeros(&model.kv_shape());
        let coefs = schedule.grid_coefficients(grid);
        let mut out = Vec::new();
        for (k, (&t, c)) in grid.iter().zip(&coefs).enumerate() {
            let o = exec.denoise(h, &x, &kv, 0, t as f64, &cond)?;
            kv = {
                // Full-image forward returns all tokens fresh.
                let mut full = Tensor::zeros(&model.kv_shape());
                full.data.copy_from_slice(&o.kv_fresh.data);
                full
            };
            sampler::ddim_update_rows(&mut x, &o.eps_patch, 0, *c);
            out.push((grid.get(k + 1).copied(), x.clone()));
        }
        Ok(out)
    };

    // ---------------------------------------------------- Theorem 1
    println!("# Thm 1 — per-step drift |x_m - x_{{m+1}}| vs M (expect O(1/M))");
    let ms = [8usize, 16, 32, 64, 128];
    let mut t1 = Table::new(&["M", "mean per-step |Δx|", "M·drift (≈const)"]);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    let mut dat = String::new();
    for &m in &ms {
        let grid = schedule.ddim_grid(m);
        let traj = run_grid(&grid, 3)?;
        let mut drifts = Vec::new();
        // Skip the last step (to clean) — it is a jump to x0_hat, not
        // a small increment; the theorem's bound is about interior
        // steps.
        for w in traj.windows(2).take(traj.len().saturating_sub(2)) {
            let d: f64 = w[0]
                .1
                .data
                .iter()
                .zip(&w[1].1.data)
                .map(|(a, b)| ((a - b).abs()) as f64)
                .sum::<f64>()
                / w[0].1.data.len() as f64;
            drifts.push(d);
        }
        let mean = stats::mean(&drifts);
        t1.row(&[
            format!("{m}"),
            format!("{mean:.5}"),
            format!("{:.3}", mean * m as f64),
        ]);
        xs.push((m as f64).ln());
        ys.push(mean.ln());
        dat.push_str(&format!("{m} {mean}\n"));
    }
    t1.print();
    let (_, slope, r2) = stats::linear_fit(&xs, &ys);
    println!("log-log slope = {slope:.3} (R² {r2:.3}); O(1/M) ⇒ ≈ -1");
    assert!(
        (-1.35..=-0.65).contains(&slope),
        "Thm 1 drift slope {slope} not ≈ -1"
    );
    expt::save_results("theory_thm1.dat", &dat)?;

    // ------------------------------------------------- Theorem 2 (a)
    // First-order consistency: the local error of one doubled step
    // (fast[ k ] -> fast[k+2]) against two single steps must scale as
    // h² — the mechanism behind Thm 2's O(n²/M²) local bound.
    println!(
        "\n# Thm 2a — local double-step vs two-single-steps error at \
         t≈600 (expect O(h²))"
    );
    let mut t2a = Table::new(&["M", "h", "local |Δx|", "local/h²·1e6"]);
    let mut xs2 = Vec::new();
    let mut ys2 = Vec::new();
    let mut dat2 = String::new();
    let cond7 = seeded_cond(&model, 7);
    let mut g7 = stadi::util::rng::NormalGen::new(7);
    let n_el: usize = model.latent_shape().iter().product();
    let x_probe = Tensor::new(model.latent_shape(), g7.vec_f32(n_el))?;
    let kv0 = Tensor::zeros(&model.kv_shape());
    for &m in &[32usize, 64, 128, 256] {
        let grid = schedule.ddim_grid(m);
        let k = (0..grid.len() - 2)
            .min_by_key(|&i| (grid[i] as i64 - 600).unsigned_abs())
            .unwrap();
        let (t0, t1, t2) = (grid[k], grid[k + 1], grid[k + 2]);
        let c0 = schedule.ddim_coefficients(t0, Some(t1));
        let c1 = schedule.ddim_coefficients(t1, Some(t2));
        let cd = schedule.ddim_coefficients(t0, Some(t2));
        let e0 = exec.denoise(h, &x_probe, &kv0, 0, t0 as f64, &cond7)?;
        let x1 = sampler::ddim_update(&x_probe, &e0.eps_patch, c0);
        let e1 = exec.denoise(h, &x1, &kv0, 0, t1 as f64, &cond7)?;
        let x2 = sampler::ddim_update(&x1, &e1.eps_patch, c1);
        let x2d = sampler::ddim_update(&x_probe, &e0.eps_patch, cd);
        let local: f64 = x2
            .data
            .iter()
            .zip(&x2d.data)
            .map(|(a, b)| (a - b).abs() as f64)
            .sum::<f64>()
            / x2.data.len() as f64;
        let hstep = (t0 - t2) as f64 / 2.0;
        t2a.row(&[
            format!("{m}"),
            format!("{hstep:.0}"),
            format!("{local:.3e}"),
            format!("{:.2}", local / (hstep * hstep) * 1e6),
        ]);
        xs2.push(hstep.ln());
        ys2.push(local.ln());
        dat2.push_str(&format!("{m} {hstep} {local}\n"));
    }
    t2a.print();
    let (_, slope2, r22) = stats::linear_fit(&xs2, &ys2);
    println!("log-log slope in h = {slope2:.3} (R² {r22:.3}); expect ≈ 2");
    assert!(
        (1.5..=2.5).contains(&slope2),
        "Thm 2 local error slope {slope2} not ≈ 2"
    );
    expt::save_results("theory_thm2_local.dat", &dat2)?;

    // ------------------------------------------------- Theorem 2 (b)
    // Operational claim: the end-to-end mixed-grid (2:1) divergence at
    // aligned timesteps stays BELOW the per-step temporal redundancy
    // the *slow device itself* tolerates (its steps span 2·T/M — that
    // is the staleness scale its buffer reuse is built on, and what
    // Thm 2 compares against via n=2).
    println!(
        "\n# Thm 2b — mixed-grid end gap vs the slow grid's per-step \
         redundancy (gap/drift must stay < 1)"
    );
    let warmup = 4usize;
    let mut t2b = Table::new(&[
        "M (fast)", "end gap", "slow per-step drift", "ratio",
    ]);
    let mut dat2b = String::new();
    for &m in &[16usize, 32, 64, 128] {
        let fast = schedule.ddim_grid(m);
        let slow = Schedule::stadi_slow_grid(&fast, warmup);
        let tf = run_grid(&fast, 7)?;
        let ts = run_grid(&slow, 7)?;
        // Gap at the final aligned state (pre-clean).
        let (_, x_f_end) = &tf[tf.len() - 2];
        let (_, x_s_end) = &ts[ts.len() - 2];
        let gap: f64 = x_f_end
            .data
            .iter()
            .zip(&x_s_end.data)
            .map(|(a, b)| (a - b).abs() as f64)
            .sum::<f64>()
            / x_f_end.data.len() as f64;
        // Per-step drift of the SLOW trajectory (the doubled-step
        // redundancy the slow device reuses buffers across).
        let mut drifts = Vec::new();
        for w in ts.windows(2).take(ts.len().saturating_sub(2)) {
            let d: f64 = w[0]
                .1
                .data
                .iter()
                .zip(&w[1].1.data)
                .map(|(a, b)| (a - b).abs() as f64)
                .sum::<f64>()
                / w[0].1.data.len() as f64;
            drifts.push(d);
        }
        let drift = stats::mean(&drifts);
        let ratio = gap / drift;
        t2b.row(&[
            format!("{m}"),
            format!("{gap:.4}"),
            format!("{drift:.4}"),
            format!("{ratio:.3}"),
        ]);
        dat2b.push_str(&format!("{m} {gap} {drift}\n"));
        assert!(
            ratio < 1.0,
            "mixed-grid gap {gap} exceeds tolerated redundancy {drift} \
             at M={m}"
        );
    }
    t2b.print();
    expt::save_results("theory_thm2_gap.dat", &dat2b)?;

    println!(
        "\nconclusion: doubled steps are first-order consistent (2a) \
         and the resulting cross-device divergence stays within the \
         staleness budget patch parallelism already tolerates (2b) — \
         the property that lets STADI cut slow-GPU steps without \
         breaking buffer alignment."
    );
    Ok(())
}
