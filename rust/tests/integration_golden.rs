//! Cross-layer golden tests: replay python-computed references through
//! the rust runtime + sampler and demand agreement.
//!
//! These are the strongest end-to-end correctness signals in the repo:
//! they cover the HLO text round-trip, the PJRT execution, the
//! cross-language PRNG, the noise schedule, and the rust-native DDIM
//! update — all at once.

use stadi::model::sampler;
use stadi::model::schedule::Schedule;
use stadi::runtime::{ExecService, Tensor};
use stadi::util::rng::NormalGen;

fn service() -> Option<ExecService> {
    if !cfg!(feature = "xla-backend") {
        eprintln!("skipping: built without xla-backend");
        return None;
    }
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(ExecService::spawn(dir).unwrap())
}

#[test]
fn trajectory_golden_replays_bit_close() {
    let Some(svc) = service() else { return };
    let exec = svc.handle();
    let model = exec.manifest().model.clone();
    let schedule = Schedule::from_info(&exec.manifest().schedule);
    let golden = exec.manifest().golden("trajectory.json").unwrap();

    let seed = golden.get("seed").unwrap().as_i64().unwrap() as u64;
    let grid = golden.get("grid").unwrap().usizes().unwrap();
    assert_eq!(grid, schedule.ddim_grid(grid.len()));

    // Inputs via the shared PCG stream: x then cond (aot.py order).
    let mut gen = NormalGen::new(seed);
    let n: usize = model.latent_shape().iter().product();
    let mut x = Tensor::new(model.latent_shape(), gen.vec_f32(n)).unwrap();
    let cond = gen.vec_f32(model.dim);

    let mut kv = Tensor::zeros(&model.kv_shape());
    let coefs = schedule.grid_coefficients(&grid);
    let steps = golden.get("steps").unwrap().as_arr().unwrap().to_vec();
    assert_eq!(steps.len(), grid.len());

    for (k, step_g) in steps.iter().enumerate() {
        let t = step_g.get("t").unwrap().as_usize().unwrap();
        assert_eq!(t, grid[k]);
        // Python recomputed the same coefficients.
        let cx = step_g.get("coef_x").unwrap().as_f64().unwrap();
        let ce = step_g.get("coef_eps").unwrap().as_f64().unwrap();
        assert!((coefs[k].coef_x - cx).abs() < 1e-9, "coef_x step {k}");
        assert!((coefs[k].coef_eps - ce).abs() < 1e-9, "coef_eps step {k}");

        let out = exec
            .denoise(model.latent_h, &x, &kv, 0, t as f64, &cond)
            .unwrap();
        // Full-image forward: fresh KV covers all tokens.
        kv = Tensor::new(model.kv_shape(), out.kv_fresh.data.clone())
            .unwrap();
        sampler::ddim_update_rows(&mut x, &out.eps_patch, 0, coefs[k]);

        let want8 = step_g.get("x_first8").unwrap().f32s().unwrap();
        for (i, w) in want8.iter().enumerate() {
            assert!(
                (x.data[i] - w).abs() < 2e-3 * w.abs().max(1.0),
                "step {k} x[{i}]: {} vs {w}",
                x.data[i]
            );
        }
        let want_sum = step_g.get("x_sum").unwrap().as_f64().unwrap();
        assert!(
            (x.sum() - want_sum).abs() < 2e-2 * want_sum.abs().max(1.0),
            "step {k} sum: {} vs {want_sum}",
            x.sum()
        );
    }
}

#[test]
fn features_golden_matches() {
    let Some(svc) = service() else { return };
    let exec = svc.handle();
    let model = exec.manifest().model.clone();
    let golden = exec.manifest().golden("features.json").unwrap();
    let seed = golden.get("seed").unwrap().as_i64().unwrap() as u64;
    let mut gen = NormalGen::new(seed);
    let n: usize = model.latent_shape().iter().product();
    let x = Tensor::new(model.latent_shape(), gen.vec_f32(n)).unwrap();
    let (f1, f2, f3) = exec.features(&x).unwrap();
    for (name, got, key) in
        [("f1", f1, "f1"), ("f2", f2, "f2"), ("f3", f3, "f3")]
    {
        let want = golden.get(key).unwrap().f32s().unwrap();
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() < 1e-4 * w.abs().max(1.0),
                "{name}[{i}]: {g} vs {w}"
            );
        }
    }
}

#[test]
fn rust_native_ddim_matches_pallas_artifact() {
    // The hot path uses the rust-native FMA; the AOT'd Pallas kernel
    // must agree bit-close for arbitrary coefficients.
    let Some(svc) = service() else { return };
    let exec = svc.handle();
    let model = exec.manifest().model.clone();
    let mut gen = NormalGen::new(99);
    let n: usize = model.latent_shape().iter().product();
    let x = Tensor::new(model.latent_shape(), gen.vec_f32(n)).unwrap();
    let eps = Tensor::new(model.latent_shape(), gen.vec_f32(n)).unwrap();
    for (cx, ce) in [(0.99, -0.05), (0.5, 0.5), (1.0, 0.0), (0.1234, -0.876)]
    {
        let art = exec.ddim_artifact(&x, &eps, cx, ce).unwrap();
        let native = sampler::ddim_update(
            &x,
            &eps,
            stadi::model::schedule::DdimCoef { coef_x: cx, coef_eps: ce },
        );
        assert_eq!(art.shape, native.shape);
        let d = art.max_abs_diff(&native);
        assert!(d < 1e-5, "ddim mismatch {d} at ({cx},{ce})");
    }
}
