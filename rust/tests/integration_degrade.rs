//! Graceful degradation under overload: shed quality, not requests.
//!
//! The scenario-storm suite pins the pressure-driven demotion ladder
//! end to end: the RNG-free degradation frontier against its committed
//! bench artifact, a live TCP storm with a deterministically stalled
//! worker (mixed tiers, deadlines, a pinned step count, a multi-res
//! request, and a mid-request occupancy collapse embedded in the stub
//! manifest's drift table), the precedence rule that adaptive
//! re-planning disarms the mid-flight lever, the bit-exactness of the
//! default (ladder-off) serve path, and `QUICKCHECK_SEED` property
//! tests over the pure ladder arithmetic.
//!
//! Everything here runs on the stub runtime — no artifacts beyond the
//! generated stub set, no xla backend, no wall-clock sleeps: the storm
//! synchronizes on events (gate entered, N requests admitted), so the
//! queue always holds exactly what the arithmetic below assumes.

use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

use stadi::config::{DegradeConfig, EngineConfig, ReplanConfig, StadiParams};
use stadi::coordinator::EngineCore;
use stadi::sched::temporal::requantize_suffix;
use stadi::serve::degrade::{
    admission_demotion, pressure_signal, rungs, tier_rank, wants_requantize,
};
use stadi::serve::router::Job;
use stadi::serve::server::{
    serve, serve_with_stats, Client, JobRunner, ServeOptions, SessionRunner,
};
use stadi::serve::sim::{simulate_degradation_frontier, DegradeSimConfig};
use stadi::spec::{GenerationSpec, Priority, Quality};
use stadi::util::json::{self, Value};
use stadi::util::proptest::{ensure, forall};

const TIERS: [Quality; 3] =
    [Quality::Draft, Quality::Standard, Quality::High];

/// Write a fresh stub artifact set into a per-test temp dir; `drift`
/// optionally embeds an occupancy schedule in the manifest so every
/// engine over the set replays the same mid-request collapse.
fn stub_artifacts(tag: &str, drift: Option<&str>) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("stadi-degrade-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let sched = drift
        .map(|s| stadi::device::OccupancySchedule::parse(s).unwrap());
    stadi::runtime::stubgen::write_stub_artifacts_with_drift(
        &dir,
        stadi::runtime::stubgen::DEFAULT_EXTRA_RESOLUTIONS,
        sched.as_ref(),
    )
    .unwrap();
    dir
}

fn config(dir: &Path, occ: &[f64]) -> EngineConfig {
    let mut cfg = EngineConfig::two_gpu_default(dir, occ);
    cfg.stadi =
        StadiParams { m_base: 6, m_warmup: 2, ..Default::default() };
    cfg
}

fn ladder(thresholds: &[f64]) -> DegradeConfig {
    DegradeConfig {
        enabled: true,
        pressure_thresholds: thresholds.to_vec(),
        floor: Quality::Draft,
    }
}

/// Relative 1e-9 closeness for numbers that crossed the JSON wire.
fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0)
}

/// Recursive 1e-9 comparison of two JSON values (same shape, same
/// strings, numbers within tolerance).
fn assert_json_close(a: &Value, b: &Value, path: &str) {
    match (a, b) {
        (Value::Num(x), Value::Num(y)) => {
            assert!(
                (x - y).abs() <= 1e-9,
                "{path}: {x} vs {y} differ by more than 1e-9"
            );
        }
        (Value::Str(x), Value::Str(y)) => {
            assert_eq!(x, y, "{path}: string mismatch");
        }
        (Value::Bool(x), Value::Bool(y)) => {
            assert_eq!(x, y, "{path}: bool mismatch");
        }
        (Value::Null, Value::Null) => {}
        (Value::Arr(xs), Value::Arr(ys)) => {
            assert_eq!(xs.len(), ys.len(), "{path}: length mismatch");
            for (i, (x, y)) in xs.iter().zip(ys).enumerate() {
                assert_json_close(x, y, &format!("{path}[{i}]"));
            }
        }
        (Value::Obj(xo), Value::Obj(yo)) => {
            assert_eq!(xo.len(), yo.len(), "{path}: key-count mismatch");
            for (k, x) in xo.iter() {
                let y = yo
                    .get(k)
                    .unwrap_or_else(|| panic!("{path}.{k}: missing"));
                assert_json_close(x, y, &format!("{path}.{k}"));
            }
        }
        _ => panic!("{path}: shape mismatch"),
    }
}

/// One-shot latch: `open()` releases every current and future
/// `wait()`. Lets the storm synchronize on *events* (gate entered, N
/// requests admitted), not on wall-clock guesses.
struct Latch(Mutex<bool>, Condvar);

impl Latch {
    fn shared() -> Arc<Latch> {
        Arc::new(Latch(Mutex::new(false), Condvar::new()))
    }

    fn open(&self) {
        *self.0.lock().unwrap() = true;
        self.1.notify_all();
    }

    fn wait(&self) {
        let mut open = self.0.lock().unwrap();
        while !*open {
            open = self.1.wait(open).unwrap();
        }
    }
}

/// Real [`SessionRunner`] whose "gate" job blocks until released —
/// the worker is pinned inside a genuine engine dispatch while the
/// storm piles up behind it, so every later job pops against a known
/// backlog. All other hooks delegate, so admission demotion, the
/// mid-flight lever, and the degrade counters are the production ones.
struct StormGate {
    inner: SessionRunner,
    release: Arc<Latch>,
    entered: Arc<Latch>,
    admitted: Arc<(Mutex<usize>, Condvar)>,
    /// How many jobs the storm queues behind the gate. Admission
    /// (`admit`) runs *before* the reader enqueues a job, so after the
    /// release the gate additionally holds until the router backlog
    /// reaches this count — every pressure computed below then reads
    /// exactly the queue the arithmetic assumes, with no race against
    /// the reader's final `submit`.
    queued: usize,
}

impl StormGate {
    fn new(inner: SessionRunner, queued: usize) -> StormGate {
        StormGate {
            inner,
            release: Latch::shared(),
            entered: Latch::shared(),
            admitted: Arc::new((Mutex::new(0), Condvar::new())),
            queued,
        }
    }

    /// Block until `n` requests have passed admission (are queued or
    /// executing).
    fn wait_admitted(&self, n: usize) {
        let (lock, cv) = &*self.admitted;
        let mut count = lock.lock().unwrap();
        while *count < n {
            count = cv.wait(count).unwrap();
        }
    }
}

impl JobRunner for StormGate {
    fn run(&self, job: &Job) -> (bool, String) {
        self.inner.run(job)
    }

    fn admit(&self, job: &Job) -> stadi::error::Result<()> {
        self.inner.admit(job)?;
        let (lock, cv) = &*self.admitted;
        *lock.lock().unwrap() += 1;
        cv.notify_all();
        Ok(())
    }

    fn shape(&self, job: &mut Job, backlog: usize) {
        self.inner.shape(job, backlog)
    }

    fn run_batched_live(
        &self,
        jobs: &[Job],
        backlog: usize,
        live_backlog: &dyn Fn() -> usize,
        record: &dyn Fn(usize),
    ) -> Vec<(bool, String)> {
        if jobs.len() == 1 && jobs[0].id == "gate" {
            self.entered.open();
            self.release.wait();
            while live_backlog() < self.queued {
                thread::yield_now();
            }
        }
        self.inner.run_batched_live(jobs, backlog, live_backlog, record)
    }

    fn degrade_counts(&self) -> (u64, u64) {
        self.inner.degrade_counts()
    }
}

/// The committed degradation frontier: ladder ON must meet strictly
/// more deadlines at every >= 2x load point while never serving below
/// the floor and giving up at most one tier of quality on average —
/// and the sweep must match `BENCH_degradation.json` at the repo root
/// number for number (the Rust DES and the python twin in
/// `scripts/gen_bench_artifacts.py` are the same arithmetic).
#[test]
fn degradation_frontier_matches_committed_bench() {
    let cfg = DegradeSimConfig::stub_fixture();
    let sweep = simulate_degradation_frontier(&cfg);
    let floor = tier_rank(cfg.degrade.floor);
    let mut overloaded = 0usize;
    let mut requantized = 0usize;
    for p in &sweep.points {
        assert_eq!(
            p.off.demoted, 0,
            "x{}: the OFF side must never touch the ladder",
            p.load_x
        );
        assert_eq!(p.off.requantized, 0, "x{}", p.load_x);
        assert!(
            p.on.min_tier >= floor,
            "x{}: served below the configured floor",
            p.load_x
        );
        // The ladder only ever sheds quality...
        assert!(
            p.on.mean_tier <= p.off.mean_tier + 1e-12,
            "x{}: ladder ON raised the mean served tier",
            p.load_x
        );
        // ...and at most one full tier of it on average.
        assert!(
            p.off.mean_tier - p.on.mean_tier <= 1.0 + 1e-12,
            "x{}: mean quality delta {} exceeds one tier",
            p.load_x,
            p.off.mean_tier - p.on.mean_tier
        );
        requantized += p.on.requantized;
        if p.load_x >= 2.0 {
            overloaded += 1;
            assert!(
                p.on.deadline_hit_rate > p.off.deadline_hit_rate,
                "x{}: ON {} vs OFF {} — overload must buy deadlines",
                p.load_x,
                p.on.deadline_hit_rate,
                p.off.deadline_hit_rate
            );
            assert!(p.on.demoted > 0, "x{}: ladder idle", p.load_x);
        }
    }
    assert!(overloaded >= 3, "sweep must cover >= 3 overload points");
    assert!(requantized > 0, "mid-flight lever never fired in the sweep");

    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap()
        .join("BENCH_degradation.json");
    let committed = std::fs::read_to_string(&path).unwrap_or_else(|_| {
        panic!(
            "{} must be committed at the repo root (regenerate with \
             scripts/gen_bench_artifacts.py)",
            path.display()
        )
    });
    assert_json_close(
        &sweep.to_json(),
        &json::parse(&committed).unwrap(),
        "degradation",
    );
}

/// The storm itself: one worker pinned inside a gate job while six
/// mixed requests queue behind it, against the ladder
/// `thresholds = [0.25, 0.6]`, `capacity = 8`. Pop order is
/// deterministic (priority, then deadline, then FIFO) so each job
/// pops against a known backlog — 5, 4, 3, 2, 1, 0 — i.e. pressures
/// 0.625, 0.5, 0.375, 0.25, 0.125, 0.0:
///
/// * `j1` (high, `steps: 7` pinned, high priority) pops first at
///   pressure 0.625 >= 0.6: never reshaped (explicit steps), but the
///   mid-flight lever re-quantizes its running suffix once;
/// * `j2` (draft + 60s deadline) is already at the floor — untouched;
/// * `j3` (high, 0.375) and `j4` (high, exactly 0.25) each arm one
///   rung and serve standard;
/// * `j5` (high, 0.125) and `j6` (standard multi-res, 0.0) are below
///   every threshold — untouched.
///
/// Every request completes: quality is shed, requests never are. The
/// stub manifest also embeds a mid-request occupancy collapse on
/// device 1 (0.6 from step 4), so the whole storm runs under drift.
#[test]
fn scenario_storm_sheds_quality_not_requests() {
    let dir = stub_artifacts("storm", Some("0@0;0@0,0.6@4"));
    let core = EngineCore::new(config(&dir, &[0.0, 0.0])).unwrap();
    let dcfg = ladder(&[0.25, 0.6]);
    let runner = Arc::new(StormGate::new(
        SessionRunner::new(core).with_degrade(&dcfg, 8),
        6,
    ));
    let release = Arc::clone(&runner.release);
    let entered = Arc::clone(&runner.entered);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let server = {
        let stop = Arc::clone(&stop);
        let runner = Arc::clone(&runner) as Arc<dyn JobRunner>;
        thread::spawn(move || {
            serve_with_stats(
                runner,
                listener,
                ServeOptions {
                    queue_capacity: 8,
                    workers: 1,
                    degrade: dcfg,
                    ..ServeOptions::default()
                },
                Some(stop),
            )
        })
    };

    let mut client = Client::connect(&addr).unwrap();
    client
        .send_spec(
            "gate",
            &GenerationSpec::new().seed(1).quality(Quality::Draft),
        )
        .unwrap();
    entered.wait();
    // The worker is pinned inside the gate job: everything below is
    // queued before any of it runs.
    client
        .send_spec(
            "j1",
            &GenerationSpec::new()
                .seed(2)
                .steps(7)
                .quality(Quality::High)
                .priority(Priority::High),
        )
        .unwrap();
    client
        .send_spec(
            "j2",
            &GenerationSpec::new()
                .seed(3)
                .quality(Quality::Draft)
                .deadline_s(60.0),
        )
        .unwrap();
    client
        .send_spec("j3", &GenerationSpec::new().seed(4).quality(Quality::High))
        .unwrap();
    client
        .send_spec("j4", &GenerationSpec::new().seed(5).quality(Quality::High))
        .unwrap();
    client
        .send_spec("j5", &GenerationSpec::new().seed(6).quality(Quality::High))
        .unwrap();
    client
        .send_spec(
            "j6",
            &GenerationSpec::new()
                .seed(7)
                .quality(Quality::Standard)
                .size(128, 256),
        )
        .unwrap();
    runner.wait_admitted(7);
    release.open();

    // Responses come back in submission order (per-connection FIFO),
    // all ok — and each echoes the tier it was actually *served* at.
    let want = [
        ("gate", "draft"),
        ("j1", "high"),
        ("j2", "draft"),
        ("j3", "standard"),
        ("j4", "standard"),
        ("j5", "high"),
        ("j6", "standard"),
    ];
    for (id, quality) in want {
        let line = client.read_line().unwrap();
        let v = json::parse(&line).unwrap();
        assert!(v.get("ok").unwrap().as_bool().unwrap(), "{line}");
        assert_eq!(v.get("id").unwrap().as_str().unwrap(), id);
        let spec = v.get("spec").unwrap();
        assert_eq!(
            spec.get("quality").unwrap().as_str().unwrap(),
            quality,
            "served tier for {id}: {line}"
        );
        if id == "j1" {
            // The pinned step count survives re-quantization: the
            // *suffix grid* thinned, the request's plan key did not.
            assert_eq!(spec.get("steps").unwrap().as_usize().unwrap(), 7);
        }
    }
    drop(client);

    stop.store(true, Ordering::SeqCst);
    let (handled, stats) = server.join().unwrap().unwrap();
    assert_eq!(handled, 7);
    assert_eq!(stats.admitted, 7);
    assert_eq!(
        stats.completed, 7,
        "graceful degradation must never shed a request"
    );
    assert_eq!(stats.failed, 0);
    assert_eq!(stats.deadline_shed, 0);
    assert_eq!(
        stats.demoted, 2,
        "exactly j3 (0.375) and j4 (exactly at the 0.25 rung)"
    );
    assert_eq!(
        stats.requantized, 1,
        "only j1 ran above the 0.6 re-quantize threshold"
    );
}

/// Precedence: when adaptive re-planning owns the sync barriers
/// (`replan.enabled`), the mid-flight lever stays disarmed — one
/// schedule surgeon per request — while the admission ladder still
/// applies. With thresholds this low, `jA` would otherwise have
/// re-quantized (pressure 0.125 >= 0.1).
#[test]
fn replan_precedence_disarms_the_midflight_lever() {
    let dir = stub_artifacts("prec", None);
    let mut cfg = config(&dir, &[0.0, 0.0]);
    cfg.replan = ReplanConfig { enabled: true, ..Default::default() };
    let core = EngineCore::new(cfg).unwrap();
    let dcfg = ladder(&[0.05, 0.1]);
    let runner = Arc::new(StormGate::new(
        SessionRunner::new(core).with_degrade(&dcfg, 8),
        2,
    ));
    let release = Arc::clone(&runner.release);
    let entered = Arc::clone(&runner.entered);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let server = {
        let stop = Arc::clone(&stop);
        let runner = Arc::clone(&runner) as Arc<dyn JobRunner>;
        thread::spawn(move || {
            serve_with_stats(
                runner,
                listener,
                ServeOptions {
                    queue_capacity: 8,
                    workers: 1,
                    degrade: dcfg,
                    ..ServeOptions::default()
                },
                Some(stop),
            )
        })
    };

    let mut client = Client::connect(&addr).unwrap();
    client
        .send_spec(
            "gate",
            &GenerationSpec::new().seed(20).quality(Quality::Draft),
        )
        .unwrap();
    entered.wait();
    // jA pops at backlog 1 -> pressure 0.125: both rungs arm, so the
    // admission ladder walks high -> standard -> draft.
    client
        .send_spec(
            "jA",
            &GenerationSpec::new().seed(21).quality(Quality::High),
        )
        .unwrap();
    client.send_spec("jB", &GenerationSpec::new().seed(22)).unwrap();
    runner.wait_admitted(3);
    release.open();

    for (id, quality) in
        [("gate", "draft"), ("jA", "draft"), ("jB", "standard")]
    {
        let line = client.read_line().unwrap();
        let v = json::parse(&line).unwrap();
        assert!(v.get("ok").unwrap().as_bool().unwrap(), "{line}");
        assert_eq!(v.get("id").unwrap().as_str().unwrap(), id);
        assert_eq!(
            v.get("spec").unwrap().get("quality").unwrap().as_str().unwrap(),
            quality,
            "{line}"
        );
    }
    drop(client);

    stop.store(true, Ordering::SeqCst);
    let (handled, stats) = server.join().unwrap().unwrap();
    assert_eq!(handled, 3);
    assert_eq!(stats.completed, 3);
    assert_eq!(
        stats.demoted, 1,
        "the admission ladder still applies under re-planning"
    );
    assert_eq!(
        stats.requantized, 0,
        "adaptive re-planning owns the barriers: the mid-flight \
         lever must stay disarmed"
    );
}

/// The default serve path (ladder disarmed) is the pre-degradation
/// one, bit for bit: the served latent equals a direct generate on an
/// independent core, tolerance only for the JSON round-trip.
#[test]
fn degrade_off_serving_stays_bit_exact() {
    let dir = stub_artifacts("off", None);
    let spec = GenerationSpec::new().seed(91);
    let baseline = EngineCore::new(config(&dir, &[0.0, 0.0]))
        .unwrap()
        .session_for(&spec)
        .unwrap()
        .execute(&spec)
        .unwrap();

    let core = EngineCore::new(config(&dir, &[0.0, 0.0])).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server = thread::spawn(move || {
        serve(
            core,
            listener,
            ServeOptions {
                queue_capacity: 4,
                workers: 1,
                max_requests: 1,
                ..ServeOptions::default()
            },
            None,
        )
    });
    let mut client = Client::connect(&addr).unwrap();
    let line = client.request_spec("b0", &spec).unwrap();
    drop(client);
    assert_eq!(server.join().unwrap().unwrap(), 1);

    let v = json::parse(&line).unwrap();
    assert!(v.get("ok").unwrap().as_bool().unwrap(), "{line}");
    let got = v.get("latent_sum").unwrap().as_f64().unwrap();
    assert!(
        close(got, baseline.latent.sum()),
        "default serve diverged from direct generate: {got} vs {}",
        baseline.latent.sum()
    );
    let Value::Arr(first8) = v.get("latent_first8").unwrap() else {
        panic!("latent_first8 missing: {line}");
    };
    assert_eq!(first8.len(), 8.min(baseline.latent.data.len()));
    for (i, x) in first8.iter().enumerate() {
        let want = f64::from(baseline.latent.data[i]);
        let got = x.as_f64().unwrap();
        assert!(close(got, want), "latent[{i}]: {got} vs {want}");
    }
    assert_eq!(
        v.get("spec").unwrap().get("quality").unwrap().as_str().unwrap(),
        "standard",
        "no ladder, no demotion"
    );
}

/// Core-level pins for the degraded executor under a mid-request
/// occupancy collapse (device 1 drops to 0.6 at step 4, from the
/// manifest's drift table):
///
/// * a probe that never fires replays the static path byte for byte
///   (this is the `degrade.enabled` default, so the OFF ladder is
///   exactly the pre-degradation engine);
/// * a probe that always fires re-quantizes exactly once (one-shot),
///   deferring the even 4-step suffix at the first barrier to the odd
///   3-step suffix at the next, and strictly reduces executed steps.
#[test]
fn occupancy_collapse_degraded_execution_is_byte_exact_until_the_lever_fires()
{
    let dir = stub_artifacts("collapse", Some("0@0;0@0,0.6@4"));
    let core = EngineCore::new(config(&dir, &[0.0, 0.0])).unwrap();
    let spec = GenerationSpec::new().seed(5);
    let session = core.session_for(&spec).unwrap();
    let base = session.execute(&spec).unwrap();

    let calm =
        session.execute_degraded_seeded(spec.seed, &mut || false).unwrap();
    assert_eq!(
        calm.latent, base.latent,
        "an armed-but-idle ladder must not change a byte"
    );
    assert!(calm.replans.is_empty());

    let forced =
        session.execute_degraded_seeded(spec.seed, &mut || true).unwrap();
    assert_eq!(
        forced.replans.len(),
        1,
        "re-quantization is one-shot per request"
    );
    let full: usize = base.stats.steps_run.iter().sum();
    let thin: usize = forced.stats.steps_run.iter().sum();
    assert!(
        thin < full,
        "the coarser suffix must run fewer steps ({thin} vs {full})"
    );
    assert_ne!(
        forced.latent, base.latent,
        "the thinned grid is a genuinely different trajectory"
    );
}

/// For a fixed snapshot, more pressure never buys more quality.
#[test]
fn prop_admission_demotion_is_monotone_in_pressure() {
    let cfg = ladder(&[0.5, 1.0, 2.0]);
    forall(
        0xD1,
        300,
        |rng| {
            (
                (rng.below(4000) as usize, rng.below(4000) as usize),
                rng.below(3) as usize,
            )
        },
        |&((a, b), t)| {
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            let q = TIERS[t % 3];
            let lo_q = admission_demotion(
                q,
                lo as f64 / 1000.0,
                &cfg,
                None,
                &mut |_| None,
            );
            let hi_q = admission_demotion(
                q,
                hi as f64 / 1000.0,
                &cfg,
                None,
                &mut |_| None,
            );
            ensure(
                tier_rank(hi_q) <= tier_rank(lo_q),
                format!(
                    "more pressure served more quality: {lo}m -> {}, \
                     {hi}m -> {}",
                    lo_q.as_str(),
                    hi_q.as_str()
                ),
            )
        },
    );
}

/// The ladder never promotes, never crosses the floor, and a tier
/// whose predicted latency fits the deadline budget is never demoted.
#[test]
fn prop_demotion_respects_floor_price_and_direction() {
    forall(
        0xD2,
        300,
        |rng| {
            (
                (rng.below(3) as usize, rng.below(3) as usize),
                rng.below(5000) as usize,
            )
        },
        |&((qi, fi), p_milli)| {
            let q = TIERS[qi % 3];
            let floor = TIERS[fi % 3];
            let cfg = DegradeConfig {
                enabled: true,
                pressure_thresholds: vec![0.5, 1.0, 2.0],
                floor,
            };
            let p = p_milli as f64 / 1000.0;
            let out = admission_demotion(q, p, &cfg, None, &mut |_| None);
            ensure(
                tier_rank(out) <= tier_rank(q),
                "the ladder promoted a request",
            )?;
            ensure(
                tier_rank(out) >= tier_rank(floor).min(tier_rank(q)),
                format!(
                    "fell through the floor: {} under floor {}",
                    out.as_str(),
                    floor.as_str()
                ),
            )?;
            // A predictor that always fits the budget vetoes every
            // rung before it demotes.
            let fits = admission_demotion(
                q,
                p,
                &cfg,
                Some(10.0),
                &mut |_| Some(0.1),
            );
            ensure(
                fits == q,
                "a request that makes its SLO was demoted",
            )?;
            // Disabled ladder is the identity at any pressure.
            let off = DegradeConfig { enabled: false, ..cfg.clone() };
            ensure(
                admission_demotion(q, p, &off, None, &mut |_| None) == q,
                "a disabled ladder moved a tier",
            )
        },
    );
}

/// Re-quantization stays on the fast grid: the coarse suffix is a
/// subsequence keeping both endpoints and exactly `(n + 1) / 2`
/// steps; even-length suffixes are the parity-deferral error case.
#[test]
fn prop_requantized_suffix_stays_on_the_fast_grid() {
    forall(
        0xD3,
        300,
        |rng| {
            let n = rng.below(41) as usize;
            (0..n).map(|_| rng.below(4) as usize).collect::<Vec<usize>>()
        },
        |raw: &Vec<usize>| {
            // Build a strictly increasing, odd-length step suffix from
            // the raw deltas — valid under any shrink of `raw`.
            let mut fast = Vec::new();
            let mut acc = 0usize;
            for &d in raw {
                acc += d + 1;
                fast.push(acc);
            }
            if fast.len() % 2 == 0 {
                fast.pop();
            }
            if fast.is_empty() {
                return ensure(
                    requantize_suffix(&fast).is_err(),
                    "an empty suffix must be rejected",
                );
            }
            let coarse =
                requantize_suffix(&fast).map_err(|e| e.to_string())?;
            ensure(
                coarse.len() == (fast.len() + 1) / 2,
                format!("kept {} of {} steps", coarse.len(), fast.len()),
            )?;
            ensure(
                coarse.first() == fast.first()
                    && coarse.last() == fast.last(),
                "the suffix endpoints must survive",
            )?;
            let mut it = fast.iter();
            ensure(
                coarse.iter().all(|c| it.any(|f| f == c)),
                "the coarse grid left the fast grid",
            )?;
            // One more step makes the length even: exactly the
            // half-step pairing the executor parity-defers on.
            let mut even = fast.clone();
            even.push(acc + 1);
            ensure(
                requantize_suffix(&even).is_err(),
                "an even suffix must defer, not re-quantize",
            )
        },
    );
}

/// Below the first threshold the whole mechanism is provably inert:
/// zero rungs, no re-quantize wish, identity at every tier.
#[test]
fn prop_pressure_below_first_threshold_is_identity() {
    forall(
        0xD4,
        300,
        |rng| {
            let steps = (0..1 + rng.below(4) as usize)
                .map(|_| rng.below(900) as usize)
                .collect::<Vec<usize>>();
            (steps, rng.below(1000) as usize)
        },
        |&(ref steps, frac)| {
            // Strictly increasing positive thresholds from raw deltas.
            let mut th = Vec::new();
            let mut acc = 0usize;
            for &d in steps {
                acc += d + 1;
                th.push(acc as f64 / 1000.0);
            }
            let p = th[0] * frac as f64 / 1000.0; // strictly < th[0]
            ensure(
                rungs(p, &th) == 0,
                format!("pressure {p} armed a rung of {th:?}"),
            )?;
            ensure(
                !wants_requantize(p, &th),
                "below every threshold yet wanting to re-quantize",
            )?;
            let cfg = DegradeConfig {
                enabled: true,
                pressure_thresholds: th.clone(),
                floor: Quality::Draft,
            };
            for q in TIERS {
                ensure(
                    admission_demotion(q, p, &cfg, None, &mut |_| None)
                        == q,
                    format!(
                        "{} demoted at pressure {p} below {}",
                        q.as_str(),
                        th[0]
                    ),
                )?;
            }
            Ok(())
        },
    );
}

/// The pressure signal itself: monotone in backlog, only ever raised
/// by a predicted deadline overrun, and guarded against a zero
/// capacity.
#[test]
fn prop_pressure_signal_is_monotone_and_guarded() {
    forall(
        0xD5,
        300,
        |rng| {
            (
                (rng.below(64) as usize, rng.below(64) as usize),
                rng.below(16) as usize,
            )
        },
        |&((a, b), cap)| {
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            let p_lo = pressure_signal(lo, cap, None, None);
            let p_hi = pressure_signal(hi, cap, None, None);
            ensure(
                p_lo <= p_hi,
                format!("backlog {lo} -> {p_lo} but {hi} -> {p_hi}"),
            )?;
            let with_deficit =
                pressure_signal(hi, cap, Some(3.0), Some(1.0));
            ensure(
                with_deficit >= p_hi,
                "a predicted overrun lowered the pressure",
            )?;
            ensure(
                pressure_signal(hi, 0, None, None) == 0.0,
                "the capacity-0 queue term must vanish",
            )
        },
    );
}
