//! Multi-resolution execution, end to end on the stub runtime.
//!
//! These tests run on every build: they generate a synthetic
//! multi-resolution artifact set (`runtime::stubgen`) into a temp
//! directory and drive the *real* engine — registry, planner, plan
//! cache, sessions, executors, serve stack, fleet — through the
//! deterministic stub backend. They pin the PR's acceptance criteria:
//!
//! * a v2 request at a registered non-native resolution executes end
//!   to end (latent sums pinned deterministic);
//! * an unregistered resolution is shed at admission with `bad_spec`
//!   and never acquires a fleet lease;
//! * the resolution-keyed `PlanCache` stays consistent while mixed
//!   resolutions hammer `plan_for` racing `calibrate`'s epoch-fenced
//!   clear, and native-spec keys still hit the default-path cache
//!   entries (cache-warm golden).

use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;

use stadi::config::{EngineConfig, StadiParams};
use stadi::coordinator::EngineCore;
use stadi::fleet::FixedGang;
use stadi::runtime::stubgen;
use stadi::serve::server::{serve_with_stats, Client, ServeOptions, SessionRunner};
use stadi::spec::GenerationSpec;
use stadi::util::json;

/// Write a fresh stub artifact set (native 32x32 latent + 16x32 +
/// 48x32) into a per-test temp dir.
fn stub_artifacts(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "stadi-multires-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    stubgen::write_stub_artifacts(&dir, stubgen::DEFAULT_EXTRA_RESOLUTIONS)
        .unwrap();
    dir
}

fn config(dir: &Path) -> EngineConfig {
    let mut cfg = EngineConfig::two_gpu_default(dir, &[0.0, 0.4]);
    cfg.stadi = StadiParams { m_base: 6, m_warmup: 2, ..Default::default() };
    cfg
}

/// Acceptance criterion: a non-native but registered resolution
/// executes end to end, deterministically; the latent has the
/// requested shape; unregistered sizes stay typed rejections.
#[test]
fn registered_non_native_resolution_executes_end_to_end() {
    let dir = stub_artifacts("e2e");
    // 128x256px -> 16x32 latent: registered by the stub set.
    let spec = GenerationSpec::new().seed(11).size(128, 256);

    let run = || {
        let core = EngineCore::new(config(&dir)).unwrap();
        core.generate(&spec).unwrap()
    };
    let a = run();
    assert_eq!(a.latent.shape, vec![16, 32, 4]);
    assert_eq!(a.plan.total_rows(), 16);
    assert!(a.timeline.total_s > 0.0);
    assert!(a.latent.abs_sum() > 0.0);
    // Pinned: a fresh engine with the same config and spec reproduces
    // the latent bit for bit (fresh profiler -> same plan -> same
    // deterministic stub numerics). A literal golden value would pin
    // the stub's *arbitrary* arithmetic — an implementation detail —
    // so the contract pinned here is determinism plus executor
    // agreement (below), the properties real artifacts also carry.
    let b = run();
    assert_eq!(a.latent, b.latent, "non-native execution not pinned");
    // Cross-executor pin: the threaded executor must reproduce the
    // dataflow numerics bit-exactly at non-native resolutions too —
    // an independent check that catches stub/executor drift.
    let mut tcfg = config(&dir);
    tcfg.mode = stadi::config::ExecMode::Threaded;
    let th = EngineCore::new(tcfg).unwrap().generate(&spec).unwrap();
    assert_eq!(
        a.latent, th.latent,
        "threaded and dataflow numerics diverge at 16x32"
    );
    // A different seed renders a different image at the same size.
    let core = EngineCore::new(config(&dir)).unwrap();
    let c = core.generate(&spec.clone().seed(12)).unwrap();
    assert!(a.latent.max_abs_diff(&c.latent) > 1e-4);

    // The high-res registered size executes too.
    let hi = core
        .generate(&GenerationSpec::new().seed(5).size(384, 256))
        .unwrap();
    assert_eq!(hi.latent.shape, vec![48, 32, 4]);
    // Native still works and still renders native-shaped latents.
    let native = core.generate(&GenerationSpec::new().seed(5)).unwrap();
    assert_eq!(native.latent.shape, vec![32, 32, 4]);

    // Unregistered (but plannable) sizes: typed Error::Spec from both
    // session_for and generate; prediction still prices them.
    let odd = GenerationSpec::new().size(192, 256); // 24x32: not compiled
    assert!(core.predict_latency_for(&odd, &[0, 1]).unwrap() > 0.0);
    let e = core.session_for(&odd).unwrap_err();
    assert!(matches!(e, stadi::error::Error::Spec(_)), "{e}");
    assert_eq!(e.wire_code(), "bad_spec");

    let _ = std::fs::remove_dir_all(&dir);
}

/// The predictor prices width, not just rows: same latent rows, wider
/// canvas, strictly more predicted seconds.
#[test]
fn predictor_scales_with_width_and_rows() {
    let dir = stub_artifacts("pred");
    let core = EngineCore::new(config(&dir)).unwrap();
    let devs = [0usize, 1];
    let native = core
        .predict_latency_for(&GenerationSpec::new(), &devs)
        .unwrap();
    let half_rows = core
        .predict_latency_for(&GenerationSpec::new().size(128, 256), &devs)
        .unwrap();
    let wide = core
        .predict_latency_for(&GenerationSpec::new().size(256, 512), &devs)
        .unwrap();
    assert!(
        half_rows < native,
        "fewer rows should predict cheaper: {half_rows} vs {native}"
    );
    assert!(
        wide > native,
        "double width should predict dearer: {wide} vs {native}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite: v2 serving over TCP on the stub runtime. A registered
/// non-native request executes and echoes its spec; an unregistered
/// resolution is rejected at admission with `bad_spec`, is never
/// admitted to the router, and never acquires a fleet lease.
#[test]
fn serve_rejects_unregistered_resolution_before_any_lease() {
    let dir = stub_artifacts("serve");
    let core = EngineCore::new(config(&dir)).unwrap();
    let fleet = core.fleet();
    let runner = SessionRunner::with_fleet(
        Arc::clone(&core),
        fleet.clone(),
        Arc::new(FixedGang(1)),
    );
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let server = {
        let stop = Arc::clone(&stop);
        thread::spawn(move || {
            serve_with_stats(
                Arc::new(runner),
                listener,
                ServeOptions {
                    queue_capacity: 8,
                    workers: 1,
                    max_requests: 0,
                    ..ServeOptions::default()
                },
                Some(stop),
            )
        })
    };

    let mut client = Client::connect(&addr).unwrap();
    // Unregistered resolution first: rejected at admission.
    let bad = GenerationSpec::new().seed(1).size(192, 256);
    let line = client.request_spec("bad", &bad).unwrap();
    let v = json::parse(&line).unwrap();
    assert!(!v.get("ok").unwrap().as_bool().unwrap(), "{line}");
    assert_eq!(v.get("code").unwrap().as_str().unwrap(), "bad_spec");
    // ...and the fleet ledger never granted a lease for it.
    assert_eq!(
        fleet.granted_total(),
        0,
        "inadmissible request acquired a lease"
    );

    // A registered non-native request executes and echoes its spec.
    let good = GenerationSpec::new().seed(21).size(128, 256);
    let line = client.request_spec("good", &good).unwrap();
    let v = json::parse(&line).unwrap();
    assert!(v.get("ok").unwrap().as_bool().unwrap(), "{line}");
    let echoed = v.get("spec").unwrap();
    assert_eq!(echoed.get("height").unwrap().as_usize().unwrap(), 128);
    assert_eq!(echoed.get("width").unwrap().as_usize().unwrap(), 256);
    assert_eq!(echoed.get("seed").unwrap().as_usize().unwrap(), 21);
    assert!(v.get("latent_sum").unwrap().as_f64().unwrap().is_finite());
    assert!(fleet.granted_total() >= 1);
    drop(client);

    stop.store(true, Ordering::SeqCst);
    let (handled, stats) = server.join().unwrap().unwrap();
    // The inadmissible request never entered the router (it is
    // counted in its own statistic): one admitted, one executed,
    // nothing failed inside the engine.
    assert_eq!(handled, 1);
    assert_eq!(stats.admitted, 1);
    assert_eq!(stats.inadmissible, 1);
    assert_eq!(stats.completed, 1);
    assert_eq!(stats.failed, 0);
    // The fleet is whole after shutdown.
    assert_eq!(fleet.in_flight(), 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite: the resolution-keyed plan cache under concurrency.
/// Mixed-resolution `plan_for` traffic hammers the cache while the
/// main thread repeatedly `calibrate`s (each calibrate swaps the cost
/// model and epoch-fences the cache). Every returned plan must match
/// its spec's shape, stats must reconcile, and after a final calibrate
/// a fresh build is observed (no stale plan survives the clear).
#[test]
fn plan_cache_survives_mixed_resolution_hammer_racing_calibrate() {
    let dir = stub_artifacts("cache");
    let mut cfg = config(&dir);
    // Cost-aware mending makes plans depend on the calibrated cost
    // model — the staleness the epoch fence exists to keep out.
    cfg.stadi.cost_aware = true;
    let core = EngineCore::new(cfg).unwrap();

    let specs: Vec<(GenerationSpec, usize)> = vec![
        (GenerationSpec::new(), 32),
        (GenerationSpec::new().size(128, 256), 16),
        (GenerationSpec::new().size(384, 256), 48),
        (GenerationSpec::new().steps(4).size(128, 256), 16),
    ];
    let stop = Arc::new(AtomicBool::new(false));
    let mut hammers = Vec::new();
    for t in 0..4usize {
        let core = Arc::clone(&core);
        let specs = specs.clone();
        let stop = Arc::clone(&stop);
        hammers.push(thread::spawn(move || {
            let mut calls = 0u64;
            let mut i = t; // stagger the per-thread spec order
            while !stop.load(Ordering::Relaxed) {
                let (spec, rows) = &specs[i % specs.len()];
                let plan = core.plan_for(spec).unwrap();
                assert_eq!(
                    plan.total_rows(),
                    *rows,
                    "plan shape diverged from its spec"
                );
                calls += 1;
                i += 1;
            }
            calls
        }));
    }
    // Let the hammers actually populate the cache before racing the
    // clears (latch on observed traffic, not on timing).
    loop {
        let s = core.plan_cache_stats();
        if s.hits + s.misses >= 8 {
            break;
        }
        thread::yield_now();
    }
    for _ in 0..5 {
        core.calibrate(1).unwrap();
        thread::yield_now();
    }
    stop.store(true, Ordering::SeqCst);
    let total: u64 = hammers.into_iter().map(|h| h.join().unwrap()).sum();
    assert!(total > 0, "hammers never ran");
    let s = core.plan_cache_stats();
    assert_eq!(s.hits + s.misses, total, "cache accounting diverged");

    // After a final clear, the next plan_for must rebuild: a stale
    // pre-clear plan being re-served would show up as a hit here.
    core.calibrate(1).unwrap();
    let before = core.plan_cache_stats();
    core.plan_for(&specs[1].0).unwrap();
    let after = core.plan_cache_stats();
    assert_eq!(
        after.misses,
        before.misses + 1,
        "stale-cost plan survived calibrate's clear"
    );

    // Cache-warm golden: the default-spec path and the legacy plan()
    // entry point share one (native, res-free) key — the second call
    // is a pure hit.
    core.plan().unwrap(); // builds (or re-hits) the native key
    let mid = core.plan_cache_stats();
    core.plan_for(&GenerationSpec::default()).unwrap();
    let end = core.plan_cache_stats();
    assert_eq!(
        end.misses, mid.misses,
        "native spec key diverged from the default-path key"
    );
    assert_eq!(end.hits, mid.hits + 1);
    let _ = std::fs::remove_dir_all(&dir);
}
