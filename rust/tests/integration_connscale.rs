//! Adversarial-client and connection-scale tests for the event-driven
//! serve front-end (`IoMode::Events`, the default on unix).
//!
//! The clients here misbehave on purpose: slow-loris drip feeding,
//! refusing to read responses, half-closing mid-line, oversized lines,
//! and pipelined requests whose completions finish out of order. Every
//! test synchronizes on events (latches, blocking reads, thread
//! joins), never on sleeps — the only sleeps below pace adversarial
//! *stimulus* (dripping bytes), and no assertion depends on their
//! timing. The connection-scale tests pin the event loop byte-identical
//! to the `--io threads` path over the same request set and pin the
//! table-full behavior (excess connections wait in the OS accept
//! backlog; zero drops).

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::Duration;

use stadi::config::IoMode;
use stadi::serve::router::{Job, RouterStats};
use stadi::serve::server::{
    serve_with_stats, Client, JobRunner, ServeOptions,
};
use stadi::util::json;

type ServerHandle = thread::JoinHandle<stadi::Result<(u64, RouterStats)>>;

/// Deterministic echo stub: the response is a pure function of the
/// request (id, seed), which is what makes the events-vs-threads
/// byte-identity comparison meaningful.
struct EchoRunner;

impl JobRunner for EchoRunner {
    fn run(&self, job: &Job) -> (bool, String) {
        (
            true,
            format!(
                "{{\"id\": \"{}\", \"ok\": true, \"seed\": {}}}",
                job.id,
                job.seed()
            ),
        )
    }
}

/// Echo stub with a fat payload so a non-reading client's response
/// queue outgrows the kernel socket buffers quickly.
struct PaddedRunner {
    pad: usize,
}

impl JobRunner for PaddedRunner {
    fn run(&self, job: &Job) -> (bool, String) {
        (
            true,
            format!(
                "{{\"id\": \"{}\", \"ok\": true, \"pad\": \"{}\"}}",
                job.id,
                "x".repeat(self.pad)
            ),
        )
    }
}

/// One-shot latch (same shape as integration_serve.rs): `open()`
/// releases every current and future `wait()`er.
struct Latch(Mutex<bool>, Condvar);

impl Latch {
    fn shared() -> Arc<Latch> {
        Arc::new(Latch(Mutex::new(false), Condvar::new()))
    }

    fn open(&self) {
        *self.0.lock().unwrap() = true;
        self.1.notify_all();
    }

    fn wait(&self) {
        let mut open = self.0.lock().unwrap();
        while !*open {
            open = self.1.wait(open).unwrap();
        }
    }
}

fn opts(queue: usize, workers: usize, io: IoMode) -> ServeOptions {
    ServeOptions {
        queue_capacity: queue,
        workers,
        io,
        ..ServeOptions::default()
    }
}

fn spawn_server(
    runner: Arc<dyn JobRunner>,
    opts: ServeOptions,
) -> (String, Arc<AtomicBool>, ServerHandle) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let handle = {
        let stop = Arc::clone(&stop);
        thread::spawn(move || {
            serve_with_stats(runner, listener, opts, Some(stop))
        })
    };
    (addr, stop, handle)
}

/// Slow-loris: one connection drips a request a few bytes at a time
/// (its line stays unterminated for many poll ticks) while a neighbor
/// runs normal traffic. The neighbor must complete fully *while the
/// loris line is still open* — joined before the loris ever finishes
/// its line — and the loris still gets its answer once it does.
#[test]
fn slow_loris_does_not_block_neighbor_connections() {
    let (addr, stop, server) =
        spawn_server(Arc::new(EchoRunner), opts(64, 2, IoMode::Events));

    let mut loris = TcpStream::connect(&addr).unwrap();
    let line = b"{\"id\": \"loris\", \"seed\": 7}\n";
    // Drip everything except the terminating newline. The sleeps pace
    // the drip so the fragments arrive on distinct poll ticks; no
    // assertion below depends on their duration.
    for chunk in line[..line.len() - 1].chunks(3) {
        loris.write_all(chunk).unwrap();
        loris.flush().unwrap();
        thread::sleep(Duration::from_millis(2));
    }

    // With the loris line guaranteed still unterminated (its last
    // byte is only sent after this join), the neighbor pipeline must
    // run to completion.
    let neighbor = {
        let addr = addr.clone();
        thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            for i in 0..20u64 {
                let line = c.request(&format!("n{i}"), i).unwrap();
                let v = json::parse(&line).unwrap();
                assert!(v.get("ok").unwrap().as_bool().unwrap(), "{line}");
                assert_eq!(
                    v.get("id").unwrap().as_str().unwrap(),
                    format!("n{i}")
                );
            }
        })
    };
    neighbor.join().unwrap();

    // Now finish the line; the drip-fed request parses and answers.
    loris.write_all(b"\n").unwrap();
    let mut reader = BufReader::new(loris.try_clone().unwrap());
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let v = json::parse(resp.trim()).unwrap();
    assert!(v.get("ok").unwrap().as_bool().unwrap(), "{resp}");
    assert_eq!(v.get("id").unwrap().as_str().unwrap(), "loris");

    drop(reader);
    drop(loris);
    stop.store(true, Ordering::SeqCst);
    let (handled, _) = server.join().unwrap().unwrap();
    assert_eq!(handled, 21);
}

/// A client that pipelines a pile of requests with fat responses and
/// refuses to read fills the kernel socket buffers; its responses back
/// up in *its own* table slot's write queue. Other connections must
/// keep flowing, and once the hog finally reads, it gets every
/// response, in submission order — nothing dropped, nothing wedged.
#[test]
fn non_reading_client_does_not_wedge_other_connections() {
    let (addr, stop, server) = spawn_server(
        Arc::new(PaddedRunner { pad: 8 * 1024 }),
        opts(256, 2, IoMode::Events),
    );

    let n_hog = 200usize;
    let mut hog = TcpStream::connect(&addr).unwrap();
    for i in 0..n_hog {
        writeln!(hog, "{{\"id\": \"hog{i}\", \"seed\": {i}}}").unwrap();
    }
    hog.flush().unwrap();
    // ~200 * 8KiB of responses head for a client that is not reading:
    // far past the loopback socket buffers, so the hog's write queue
    // is stalled while the neighbor runs.

    let neighbor = {
        let addr = addr.clone();
        thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            for i in 0..50u64 {
                let line = c.request(&format!("n{i}"), i).unwrap();
                let v = json::parse(&line).unwrap();
                assert!(v.get("ok").unwrap().as_bool().unwrap(), "{line}");
                assert_eq!(
                    v.get("id").unwrap().as_str().unwrap(),
                    format!("n{i}")
                );
            }
        })
    };
    neighbor.join().unwrap();

    // The hog starts reading (well before the stalled-writer reaper's
    // WRITE_TIMEOUT): every response arrives, in per-connection FIFO.
    let mut reader = BufReader::new(hog.try_clone().unwrap());
    let mut line = String::new();
    for i in 0..n_hog {
        line.clear();
        reader.read_line(&mut line).unwrap();
        let v = json::parse(line.trim()).unwrap();
        assert!(v.get("ok").unwrap().as_bool().unwrap());
        assert_eq!(
            v.get("id").unwrap().as_str().unwrap(),
            format!("hog{i}"),
            "hog responses out of order or dropped"
        );
    }

    drop(reader);
    drop(hog);
    stop.store(true, Ordering::SeqCst);
    let (handled, _) = server.join().unwrap().unwrap();
    assert_eq!(handled, n_hog as u64 + 50);
}

/// Mid-line half-close: the client sends one complete request plus a
/// final line with no trailing newline, then shuts down its write
/// side. The final unterminated line must still parse and answer
/// (matching the threads-mode `read_line` semantics), after which the
/// server closes the connection cleanly.
#[test]
fn mid_line_half_close_still_answers_the_final_partial_line() {
    let (addr, stop, server) =
        spawn_server(Arc::new(EchoRunner), opts(64, 2, IoMode::Events));

    let mut stream = TcpStream::connect(&addr).unwrap();
    writeln!(stream, "{{\"id\": \"full\", \"seed\": 1}}").unwrap();
    // Complete JSON, missing only the newline — then half-close.
    stream
        .write_all(b"{\"id\": \"partial\", \"seed\": 2}")
        .unwrap();
    stream.flush().unwrap();
    stream.shutdown(Shutdown::Write).unwrap();

    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    for want in ["full", "partial"] {
        line.clear();
        reader.read_line(&mut line).unwrap();
        let v = json::parse(line.trim()).unwrap();
        assert!(v.get("ok").unwrap().as_bool().unwrap(), "{line}");
        assert_eq!(v.get("id").unwrap().as_str().unwrap(), want);
    }
    // Both owed responses delivered; the server drops the connection.
    line.clear();
    assert_eq!(reader.read_line(&mut line).unwrap(), 0, "expected EOF");

    drop(reader);
    drop(stream);
    stop.store(true, Ordering::SeqCst);
    let (handled, _) = server.join().unwrap().unwrap();
    assert_eq!(handled, 2);
}

/// An oversized line (beyond the event path's 64 KiB frame cap) gets
/// a typed `bad_request` answer and is discarded to its newline; the
/// connection survives and the next request is served normally, in
/// FIFO position behind the error.
#[cfg(unix)]
#[test]
fn oversized_line_gets_bad_request_and_connection_survives() {
    let (addr, stop, server) =
        spawn_server(Arc::new(EchoRunner), opts(64, 2, IoMode::Events));

    let mut stream = TcpStream::connect(&addr).unwrap();
    let junk = vec![b'x'; 80 * 1024];
    stream.write_all(&junk).unwrap();
    stream.write_all(b"\n").unwrap();
    writeln!(stream, "{{\"id\": \"after\", \"seed\": 3}}").unwrap();
    stream.flush().unwrap();

    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = json::parse(line.trim()).unwrap();
    assert!(!v.get("ok").unwrap().as_bool().unwrap(), "{line}");
    assert_eq!(v.get("code").unwrap().as_str().unwrap(), "bad_request");
    line.clear();
    reader.read_line(&mut line).unwrap();
    let v = json::parse(line.trim()).unwrap();
    assert!(v.get("ok").unwrap().as_bool().unwrap(), "{line}");
    assert_eq!(v.get("id").unwrap().as_str().unwrap(), "after");

    drop(reader);
    drop(stream);
    stop.store(true, Ordering::SeqCst);
    let (_, stats) = server.join().unwrap().unwrap();
    assert_eq!(stats.oversized, 1, "oversize not counted in stats");
}

/// Two pipelined requests whose completions are forced out of order
/// (the first blocks until the second has executed) must come back in
/// submission order — the table's per-connection reorder buffer at
/// work, latch-gated with no sleeps.
#[test]
fn pipelined_out_of_order_completions_reorder_per_connection() {
    struct HandoffRunner {
        fast_done: Arc<Latch>,
        exec_order: Arc<Mutex<Vec<String>>>,
    }

    impl JobRunner for HandoffRunner {
        fn run(&self, job: &Job) -> (bool, String) {
            if job.id == "slow" {
                // Popped first (FIFO), finishes last: parked until
                // "fast" has recorded its execution.
                self.fast_done.wait();
            } else {
                self.exec_order.lock().unwrap().push(job.id.clone());
                self.fast_done.open();
            }
            if job.id == "slow" {
                self.exec_order.lock().unwrap().push(job.id.clone());
            }
            (true, format!("{{\"id\": \"{}\", \"ok\": true}}", job.id))
        }
    }

    let fast_done = Latch::shared();
    let exec_order = Arc::new(Mutex::new(Vec::new()));
    let runner = Arc::new(HandoffRunner {
        fast_done: Arc::clone(&fast_done),
        exec_order: Arc::clone(&exec_order),
    });
    let (addr, stop, server) =
        spawn_server(runner, opts(8, 2, IoMode::Events));

    let mut client = Client::connect(&addr).unwrap();
    client.send("slow", 0).unwrap();
    client.send("fast", 1).unwrap();
    for want in ["slow", "fast"] {
        let line = client.read_line().unwrap();
        let v = json::parse(&line).unwrap();
        assert!(v.get("ok").unwrap().as_bool().unwrap(), "{line}");
        assert_eq!(v.get("id").unwrap().as_str().unwrap(), want);
    }
    drop(client);
    stop.store(true, Ordering::SeqCst);
    server.join().unwrap().unwrap();
    // Execution (completion) order really was inverted relative to
    // what the client observed.
    assert_eq!(*exec_order.lock().unwrap(), vec!["fast", "slow"]);
}

/// Connection-scale smoke: 512 concurrent clients through the event
/// loop on the stub backend, every response correct and in
/// per-connection FIFO order — then the same request set replayed
/// through the `--io threads` path must produce byte-identical
/// response lines per request.
#[test]
fn event_loop_512_clients_byte_identical_to_threads_path() {
    let n_clients = 512usize;
    let per_client = 2usize;

    let collect_events = {
        let (addr, stop, server) = spawn_server(
            Arc::new(EchoRunner),
            ServeOptions {
                queue_capacity: 1024,
                workers: 4,
                max_connections: n_clients,
                io: IoMode::Events,
                ..ServeOptions::default()
            },
        );
        let handles: Vec<_> = (0..n_clients)
            .map(|c| {
                let addr = addr.clone();
                thread::spawn(move || {
                    let mut client = Client::connect(&addr).unwrap();
                    for j in 0..per_client {
                        client
                            .send(
                                &format!("c{c}-{j}"),
                                (c * 31 + j * 7) as u64,
                            )
                            .unwrap();
                    }
                    let mut out = Vec::new();
                    for j in 0..per_client {
                        let line = client.read_line().unwrap();
                        let v = json::parse(&line).unwrap();
                        // Per-connection FIFO at scale.
                        assert_eq!(
                            v.get("id").unwrap().as_str().unwrap(),
                            format!("c{c}-{j}"),
                            "client {c} out of order"
                        );
                        out.push((format!("c{c}-{j}"), line));
                    }
                    out
                })
            })
            .collect();
        let mut map = BTreeMap::new();
        for h in handles {
            for (id, line) in h.join().unwrap() {
                map.insert(id, line);
            }
        }
        stop.store(true, Ordering::SeqCst);
        let (handled, stats) = server.join().unwrap().unwrap();
        assert_eq!(handled, (n_clients * per_client) as u64);
        #[cfg(unix)]
        assert!(
            stats.lazy_parsed > 0,
            "event path never took the lazy parse: {stats:?}"
        );
        let _ = stats;
        map
    };

    // Replay the identical request set through the thread-per-
    // connection path (one sequential client is enough: the response
    // is a pure function of the request, and this run's job is to pin
    // the wire bytes, not concurrency).
    let collect_threads = {
        let (addr, stop, server) = spawn_server(
            Arc::new(EchoRunner),
            opts(1024, 4, IoMode::Threads),
        );
        let mut client = Client::connect(&addr).unwrap();
        let mut map = BTreeMap::new();
        for c in 0..n_clients {
            for j in 0..per_client {
                let id = format!("c{c}-{j}");
                let line = client
                    .request(&id, (c * 31 + j * 7) as u64)
                    .unwrap();
                map.insert(id, line);
            }
        }
        drop(client);
        stop.store(true, Ordering::SeqCst);
        let (handled, stats) = server.join().unwrap().unwrap();
        assert_eq!(handled, (n_clients * per_client) as u64);
        assert_eq!(
            stats.lazy_parsed, 0,
            "threads path must keep the full-tree parse"
        );
        map
    };

    assert_eq!(
        collect_events, collect_threads,
        "event-loop responses diverge from the threads path"
    );
}

/// Table-full behavior: with a 4-slot connection table and 16 clients
/// arriving at once, the excess waits in the OS accept backlog (the
/// event loop deregisters the listener while the table is full) and
/// every single client is served — zero drops, zero errors.
#[test]
fn table_full_connections_wait_in_accept_backlog_zero_drops() {
    let n_clients = 16usize;
    let (addr, stop, server) = spawn_server(
        Arc::new(EchoRunner),
        ServeOptions {
            queue_capacity: 64,
            workers: 2,
            max_connections: 4,
            io: IoMode::Events,
            ..ServeOptions::default()
        },
    );

    let handles: Vec<_> = (0..n_clients)
        .map(|c| {
            let addr = addr.clone();
            thread::spawn(move || {
                // Connect, one round trip, disconnect — freeing a
                // table slot for whoever is parked in the backlog.
                let mut client = Client::connect(&addr).unwrap();
                let line =
                    client.request(&format!("q{c}"), c as u64).unwrap();
                let v = json::parse(&line).unwrap();
                assert!(v.get("ok").unwrap().as_bool().unwrap(), "{line}");
                assert_eq!(
                    v.get("id").unwrap().as_str().unwrap(),
                    format!("q{c}")
                );
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    stop.store(true, Ordering::SeqCst);
    let (handled, stats) = server.join().unwrap().unwrap();
    assert_eq!(handled, n_clients as u64, "a queued connection was dropped");
    assert_eq!(stats.admitted, n_clients as u64);
    assert_eq!(stats.completed, n_clients as u64);
}
