//! Displaced halo exchange, end to end on the stub runtime — runs on
//! every build. These tests pin the PR's acceptance criteria:
//!
//! * on a slow-interconnect heterogeneous cluster (comm-bound under
//!   the synchronous exchange) the displaced mode's simulated AND
//!   stub-executed virtual makespan strictly beats `HaloMode::Sync`;
//! * the PSNR/SSIM (+LPIPS-proxy) quality gate passes at every
//!   quality tier's staleness budget — drift is *measured* (the stub
//!   set carries a `kv_gain` coupling so stale halos actually move the
//!   numerics), not assumed;
//! * `max_staleness = 0` (and the High tier, which tightens any
//!   configured budget to 0) is byte-identical to the sync path —
//!   latents, timeline floats and halo counters;
//! * property test: for random clusters and budgets, budget-0 stays
//!   bit-identical, and the fallback counter matches the plan's
//!   displaced-fallback rule exactly (warmup prefix, first `budget`
//!   syncs and the final sync always run the blocking exchange — the
//!   audit that no consumer ever reads a halo older than its budget).

use std::path::{Path, PathBuf};

use stadi::config::{
    CommConfig, EngineConfig, ExecMode, HaloMode, StadiParams,
    UnevenStrategy,
};
use stadi::coordinator::EngineCore;
use stadi::metrics::{lpips::lpips, psnr::psnr, ssim::ssim};
use stadi::runtime::stubgen;
use stadi::spec::{GenerationSpec, Quality};

/// Stub artifact set with the KV coupling gain: every device's eps
/// depends on the neighbor-published KV context, so halo staleness is
/// numerically measurable (without it the stub's arithmetic is purely
/// local and the quality gate would measure nothing).
fn stub_artifacts(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("stadi-halo-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    stubgen::write_stub_artifacts_full(&dir, &[], None, Some(0.05))
        .unwrap();
    dir
}

/// A slow interconnect on which the blocking x gather is a large
/// fraction of every sync interval (comm-bound under `Sync`).
fn slow_comm() -> CommConfig {
    CommConfig {
        latency_s: 0.02,
        bandwidth_bytes_per_s: 2e7,
        uneven_strategy: UnevenStrategy::PadAllGather,
    }
}

fn config(dir: &Path, halo: HaloMode) -> EngineConfig {
    let mut cfg = EngineConfig::two_gpu_default(dir, &[0.0, 0.5]);
    cfg.stadi =
        StadiParams { m_base: 16, m_warmup: 2, ..Default::default() };
    cfg.comm = slow_comm();
    cfg.halo = halo;
    cfg
}

/// Acceptance criterion: simulated + stub-executed makespan win on the
/// comm-bound cluster, with agreeing executor/timeline counters and
/// bit-equal numerics across both executors.
#[test]
fn displaced_strictly_beats_sync_makespan_on_comm_bound_cluster() {
    let dir = stub_artifacts("makespan");
    // Standard quality: tier budget 1 == the configured budget.
    let spec = GenerationSpec::new().seed(9).quality(Quality::Standard);
    let disp_mode = HaloMode::Displaced { max_staleness: 1 };

    let sync = EngineCore::new(config(&dir, HaloMode::Sync))
        .unwrap()
        .generate(&spec)
        .unwrap();
    // Premise: the fixture really is comm-bound under sync.
    assert!(
        sync.timeline.comm_s > 0.2 * sync.timeline.total_s,
        "fixture not comm-bound: comm {} of {}",
        sync.timeline.comm_s,
        sync.timeline.total_s
    );
    assert_eq!(sync.timeline.halo_displaced, 0);

    let disp_core = EngineCore::new(config(&dir, disp_mode)).unwrap();
    let disp = disp_core.generate(&spec).unwrap();
    // Stub-executed virtual makespan strictly beats sync.
    assert!(
        disp.timeline.total_s < sync.timeline.total_s,
        "displaced {} !< sync {}",
        disp.timeline.total_s,
        sync.timeline.total_s
    );
    assert!(disp.timeline.comm_s < sync.timeline.comm_s);
    assert!(disp.stats.halo_displaced > 0, "no sync ran displaced");
    assert_eq!(
        disp.stats.halo_displaced + disp.stats.halo_fallback,
        disp.stats.syncs
    );
    // Executor counters agree with the virtual timeline's.
    assert_eq!(disp.stats.halo_displaced, disp.timeline.halo_displaced);
    assert_eq!(disp.stats.halo_fallback, disp.timeline.halo_fallback);
    // Overlap accounting surfaces the hidden transfers.
    assert!(disp.timeline.overlap_s.iter().sum::<f64>() > 0.0);

    // The *simulated* (predictor) side sees the same win — gang
    // policies size displaced gangs by the cheaper effective comm.
    let p_sync = EngineCore::new(config(&dir, HaloMode::Sync))
        .unwrap()
        .predict_latency_for(&spec, &[0, 1])
        .unwrap();
    let p_disp = disp_core.predict_latency_for(&spec, &[0, 1]).unwrap();
    assert!(p_disp < p_sync, "predicted {p_disp} !< {p_sync}");

    // Cross-executor pin: the threaded executor's displaced protocol
    // (publish → barrier → peek) reproduces dataflow bit for bit.
    let mut tcfg = config(&dir, disp_mode);
    tcfg.mode = ExecMode::Threaded;
    let th = EngineCore::new(tcfg).unwrap().generate(&spec).unwrap();
    assert_eq!(
        disp.latent, th.latent,
        "threaded and dataflow displaced numerics diverge"
    );
    assert_eq!(disp.stats.halo_displaced, th.stats.halo_displaced);
    assert_eq!(disp.stats.halo_fallback, th.stats.halo_fallback);
    assert_eq!(disp.stats.x_bytes, th.stats.x_bytes);
    assert_eq!(disp.stats.kv_bytes, th.stats.kv_bytes);
    let _ = std::fs::remove_dir_all(&dir);
}

/// `max_staleness = 0` ≡ today's sync path, byte for byte — and the
/// High quality tier tightens *any* configured budget to 0.
#[test]
fn budget_zero_and_high_tier_are_byte_identical_to_sync() {
    let dir = stub_artifacts("budget0");
    let spec = GenerationSpec::new().seed(11);
    for mode in [ExecMode::Dataflow, ExecMode::Threaded] {
        let run = |halo: HaloMode| {
            let mut cfg = config(&dir, halo);
            cfg.mode = mode;
            EngineCore::new(cfg).unwrap().generate(&spec).unwrap()
        };
        let sync = run(HaloMode::Sync);
        let d0 = run(HaloMode::Displaced { max_staleness: 0 });
        assert_eq!(sync.latent, d0.latent, "{mode:?} latents diverged");
        assert_eq!(sync.timeline.total_s, d0.timeline.total_s);
        assert_eq!(sync.timeline.busy_s, d0.timeline.busy_s);
        assert_eq!(sync.timeline.comm_s, d0.timeline.comm_s);
        assert_eq!(sync.timeline.overlap_s, d0.timeline.overlap_s);
        assert_eq!(
            sync.timeline.halo_fallback,
            d0.timeline.halo_fallback
        );
        assert_eq!(d0.timeline.halo_displaced, 0);
        assert_eq!(d0.stats.halo_displaced, 0);
        assert_eq!(sync.stats.x_bytes, d0.stats.x_bytes);
        assert_eq!(sync.stats.kv_bytes, d0.stats.kv_bytes);
    }
    // High tier on a budget-2 engine: effective budget 0, identical to
    // the sync engine under the same spec.
    let high = GenerationSpec::new().seed(11).quality(Quality::High);
    let sync_high = EngineCore::new(config(&dir, HaloMode::Sync))
        .unwrap()
        .generate(&high)
        .unwrap();
    let disp_core = EngineCore::new(config(
        &dir,
        HaloMode::Displaced { max_staleness: 2 },
    ))
    .unwrap();
    assert_eq!(
        disp_core.effective_halo(Some(&high)).max_staleness(),
        0
    );
    let disp_high = disp_core.generate(&high).unwrap();
    assert_eq!(sync_high.latent, disp_high.latent);
    assert_eq!(disp_high.stats.halo_displaced, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The quality gate: displaced-vs-sync PSNR/SSIM/LPIPS per tier, each
/// tier measured at its own staleness budget on a budget-2 engine.
/// The floors are deliberately conservative — the point is that drift
/// exists, is bounded, and is *measured* per budget.
#[test]
fn quality_gate_psnr_ssim_lpips_within_per_tier_floors() {
    let dir = stub_artifacts("gate");
    let tiers = [
        // (tier, psnr floor dB, ssim floor, lpips ceiling)
        (Quality::Draft, 25.0, 0.85, 0.05),
        (Quality::Standard, 30.0, 0.90, 0.05),
    ];
    for (q, psnr_floor, ssim_floor, lpips_ceil) in tiers {
        // Explicit steps win over the tier's step scaling, pinning a
        // plan with enough sync points that both budgets engage; the
        // tier still sets the staleness budget.
        let spec = GenerationSpec::new().seed(5).steps(24).quality(q);
        let sync = EngineCore::new(config(&dir, HaloMode::Sync))
            .unwrap()
            .generate(&spec)
            .unwrap();
        let disp_core = EngineCore::new(config(
            &dir,
            HaloMode::Displaced { max_staleness: 2 },
        ))
        .unwrap();
        let disp = disp_core.generate(&spec).unwrap();
        assert!(
            disp.stats.halo_displaced > 0,
            "{q:?}: staleness never engaged"
        );
        // The coupling makes staleness *visible*: outputs differ...
        assert_ne!(
            sync.latent, disp.latent,
            "{q:?}: displaced output identical — the gate measures \
             nothing (kv_gain coupling lost?)"
        );
        // ...but inside the tier's floor.
        let p = psnr(&sync.latent, &disp.latent);
        let s = ssim(&sync.latent, &disp.latent);
        let l = lpips(disp_core.exec(), &sync.latent, &disp.latent)
            .unwrap();
        assert!(
            p >= psnr_floor,
            "{q:?}: PSNR {p:.2} dB below floor {psnr_floor}"
        );
        assert!(
            s >= ssim_floor,
            "{q:?}: SSIM {s:.4} below floor {ssim_floor}"
        );
        assert!(
            l <= lpips_ceil,
            "{q:?}: LPIPS {l:.5} above ceiling {lpips_ceil}"
        );
    }
    // High tier: budget 0, exact — asserted byte-identical in
    // `budget_zero_and_high_tier_are_byte_identical_to_sync`.
    let _ = std::fs::remove_dir_all(&dir);
}

/// Property test (QUICKCHECK_SEED-honoring): random straggler
/// occupancies × staleness budgets. Budget 0 is bit-identical to
/// Sync; for every budget the executor's fallback counter matches the
/// plan's displaced-fallback rule *exactly* — which is the audit that
/// no consumer ever read a halo older than its budget (the executor
/// errors out if the history entry `si - budget` is missing, and the
/// threaded path debug-asserts each peeked version).
#[test]
fn property_budget_zero_identity_and_fallback_rule_audit() {
    use stadi::util::proptest::{ensure, forall};
    let dir = stub_artifacts("prop");
    forall(
        173,
        12,
        |rng| {
            let occ = 0.7 * rng.next_f64();
            let budget = rng.below(3) as usize; // 0 | 1 | 2
            let seed = rng.below(1 << 20) as u64;
            (occ, (budget, seed))
        },
        |&(occ, (budget, seed))| {
            // Draft tier: its budget (2) never tightens the configured
            // one, so the effective budget is exactly `budget`; the
            // explicit step count keeps the plan large enough that
            // budgets 1 and 2 actually displace some syncs.
            let spec = GenerationSpec::new()
                .seed(seed)
                .steps(16)
                .quality(Quality::Draft);
            let mut base = config(&dir, HaloMode::Sync);
            base.devices[1].occupancy = occ;
            let sync = EngineCore::new(base.clone())
                .map_err(|e| e.to_string())?
                .generate(&spec)
                .map_err(|e| e.to_string())?;
            let mut dcfg = base.clone();
            dcfg.halo = HaloMode::Displaced { max_staleness: budget };
            let disp = EngineCore::new(dcfg)
                .map_err(|e| e.to_string())?
                .generate(&spec)
                .map_err(|e| e.to_string())?;

            if budget == 0 {
                ensure(
                    sync.latent == disp.latent,
                    format!("budget-0 latents diverged (occ {occ})"),
                )?;
                ensure(
                    sync.timeline.total_s == disp.timeline.total_s,
                    "budget-0 timeline diverged",
                )?;
                ensure(
                    disp.stats.halo_displaced == 0,
                    "budget-0 ran a displaced sync",
                )?;
            }
            // Counters conserve and agree with the virtual timeline.
            ensure(
                disp.stats.halo_displaced + disp.stats.halo_fallback
                    == disp.stats.syncs,
                "halo counters do not partition the syncs",
            )?;
            ensure(
                disp.stats.halo_displaced
                    == disp.timeline.halo_displaced,
                "executor/timeline displaced counters disagree",
            )?;
            ensure(
                disp.stats.halo_fallback == disp.timeline.halo_fallback,
                "executor/timeline fallback counters disagree",
            )?;
            // The fallback counter matches the plan's rule exactly:
            // warmup prefix, the first `budget` syncs and the final
            // sync block; everything else runs displaced.
            let n = disp.plan.sync_points.len();
            let expected = (0..n)
                .filter(|&si| disp.plan.displaced_fallback(si, budget))
                .count();
            ensure(
                disp.stats.halo_fallback == expected,
                format!(
                    "fallback counter {} != rule {} (budget {budget})",
                    disp.stats.halo_fallback, expected
                ),
            )?;
            Ok(())
        },
    );
    let _ = std::fs::remove_dir_all(&dir);
}
