//! Wire-protocol v2 / `GenerationSpec` backcompat goldens.
//!
//! The redesign's contract: a v1 `{"id","seed"}` line maps to the
//! default spec, and the default spec plans exactly like the
//! pre-redesign engine (global `Schedule`, config M_base/M_warmup,
//! native latent rows). These tests pin that numerically — the v1
//! serve path must reproduce, bit for bit, the latent the old
//! `Plan::build`-from-globals path produces. Real execution needs
//! artifacts + the xla backend and skips otherwise.

use std::net::TcpListener;
use std::thread;

use stadi::config::{EngineConfig, StadiParams};
use stadi::coordinator::EngineCore;
use stadi::sched::plan::Plan;
use stadi::serve::server::{serve, Client, ServeOptions};
use stadi::spec::GenerationSpec;
use stadi::util::json;

fn config() -> Option<EngineConfig> {
    if !cfg!(feature = "xla-backend") {
        eprintln!("skipping: built without xla-backend");
        return None;
    }
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let mut cfg = EngineConfig::two_gpu_default(dir, &[0.0, 0.4]);
    cfg.stadi = StadiParams { m_base: 6, m_warmup: 2, ..Default::default() };
    Some(cfg)
}

/// The literal pre-redesign planning path: `Plan::build` straight from
/// the engine's globals (schedule, config params, native model dims)
/// at current effective speeds — what `EngineCore::plan` used to
/// inline before specs existed.
fn pre_redesign_plan(core: &EngineCore) -> Plan {
    let m = core.exec().manifest().model.clone();
    let names: Vec<String> = core
        .config()
        .devices
        .iter()
        .map(|d| d.name.clone())
        .collect();
    Plan::build(
        core.schedule(),
        &core.effective_speeds(),
        &names,
        &core.config().stadi,
        m.latent_h,
        m.row_granularity,
    )
    .unwrap()
}

/// Golden backcompat: one v1 wire request against a fresh server
/// produces the exact `latent_sum`/`latent_first8` of the
/// pre-redesign path on a fresh engine with the same config.
#[test]
fn v1_wire_line_reproduces_pre_redesign_numerics() {
    let Some(cfg) = config() else { return };
    let seed = 4242u64;

    // Reference: fresh engine, old-style plan from globals, executed
    // through the explicit-plan escape hatch (no spec involved).
    let reference = {
        let core = EngineCore::new(cfg.clone()).unwrap();
        let plan = pre_redesign_plan(&core);
        core.session_with_plan(plan).execute_seeded(seed).unwrap()
    };
    let want_sum = reference.latent.sum();
    let want_first8: Vec<f64> = reference.latent.data[..8]
        .iter()
        .map(|&x| x as f64)
        .collect();

    // Candidate: the same request as a raw v1 line through the full
    // serve stack (parse -> default spec -> plan_for -> execute).
    let core = EngineCore::new(cfg).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let client_thread = thread::spawn(move || {
        let mut client = Client::connect(&addr).unwrap();
        client.request("golden", seed).unwrap()
    });
    let opts = ServeOptions {
        queue_capacity: 4,
        workers: 1,
        max_requests: 1,
        ..ServeOptions::default()
    };
    serve(core, listener, opts, None).unwrap();
    let line = client_thread.join().unwrap();

    let v = json::parse(&line).unwrap();
    assert!(v.get("ok").unwrap().as_bool().unwrap(), "{line}");
    // Exact equality: same f32 latents, f64-summed and round-trip
    // serialized with shortest-exact formatting on both sides.
    assert_eq!(
        v.get("latent_sum").unwrap().as_f64().unwrap(),
        want_sum,
        "latent_sum drifted from the pre-redesign path: {line}"
    );
    let got_first8 = v.get("latent_first8").unwrap().f64s().unwrap();
    assert_eq!(got_first8, want_first8, "latent_first8 drifted: {line}");
    // The response also echoes the resolved (default) spec.
    let spec = v.get("spec").unwrap();
    assert_eq!(spec.get("seed").unwrap().as_usize().unwrap(), seed as usize);
    assert_eq!(
        spec.get("quality").unwrap().as_str().unwrap(),
        "standard"
    );
    assert_eq!(
        spec.get("priority").unwrap().as_str().unwrap(),
        "normal"
    );
}

/// The same equivalence at the library layer: `plan()` (default spec,
/// cached) and the pre-redesign inline build agree on every
/// plan-shaping output.
#[test]
fn default_spec_plan_equals_pre_redesign_plan() {
    let Some(cfg) = config() else { return };
    let core = EngineCore::new(cfg).unwrap();
    let old = pre_redesign_plan(&core);
    let new = core.plan_for(&GenerationSpec::default()).unwrap();
    assert_eq!(old.params.m_base, new.params.m_base);
    assert_eq!(old.params.m_warmup, new.params.m_warmup);
    assert_eq!(old.sync_points, new.sync_points);
    assert_eq!(old.devices.len(), new.devices.len());
    for (a, b) in old.devices.iter().zip(&new.devices) {
        assert_eq!(a.rows, b.rows);
        assert_eq!(a.class, b.class);
        assert_eq!(a.steps.len(), b.steps.len());
    }
}
