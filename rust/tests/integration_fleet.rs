//! Fleet-allocation integration tests — all offline (no artifacts):
//! the gang-policy DES drives the *real* `FleetManager` ledger, and
//! per-gang latencies come from the real Eq. 4/5 planner + timeline
//! simulator, so the latency–throughput tradeoff measured here is the
//! one the serving stack exhibits.

use stadi::config::{CommConfig, DeviceConfig, StadiParams};
use stadi::coordinator::timeline;
use stadi::device::{build_cluster, CostModel, SimGpu};
use stadi::fleet::{Adaptive, AllGpus, FixedGang, GangPolicy};
use stadi::model::schedule::Schedule;
use stadi::runtime::artifacts::ModelInfo;
use stadi::sched::plan::Plan;
use stadi::serve::sim::{
    assert_leases_disjoint, simulate_gang_policy, GangSimStats,
};

/// The paper-shaped toy model geometry (same as the timeline tests).
fn model() -> ModelInfo {
    ModelInfo {
        latent_h: 32,
        latent_w: 32,
        latent_c: 4,
        patch: 2,
        dim: 96,
        heads: 4,
        layers: 3,
        temb_dim: 64,
        row_granularity: 4,
        tokens_full: 256,
        param_count: 1,
        params_seed: 0,
    }
}

/// 4-GPU heterogeneous cluster: one idle flagship down to a 50%-busy
/// straggler.
const OCC: [f64; 4] = [0.0, 0.1, 0.2, 0.5];

fn cluster() -> Vec<SimGpu> {
    let devs: Vec<DeviceConfig> = OCC
        .iter()
        .enumerate()
        .map(|(i, &o)| DeviceConfig::new(format!("gpu{i}"), 1.0, o))
        .collect();
    build_cluster(&devs, CostModel { fixed_s: 0.004, per_row_s: 0.0012 })
}

fn speeds() -> Vec<f64> {
    OCC.iter().map(|&o| 1.0 - o).collect()
}

/// Gang latency = plan the subset with the real allocators, replay it
/// on the simulated timeline. The cluster/speeds/schedule are built
/// once — this runs per candidate prefix per admission attempt.
fn latency_of(gang: &[usize]) -> Option<f64> {
    use std::sync::OnceLock;
    static CTX: OnceLock<(Vec<SimGpu>, Vec<f64>, Schedule)> =
        OnceLock::new();
    let (cl, all, schedule) = CTX.get_or_init(|| {
        (cluster(), speeds(), Schedule::scaled_linear(1000, 0.00085, 0.012))
    });
    let sub_speeds: Vec<f64> = gang.iter().map(|&d| all[d]).collect();
    let names: Vec<String> =
        gang.iter().map(|&d| format!("gpu{d}")).collect();
    let m = model();
    let plan = Plan::build(
        schedule,
        &sub_speeds,
        &names,
        &StadiParams::default(),
        m.latent_h,
        m.row_granularity,
    )
    .ok()?;
    let sub: Vec<SimGpu> = gang.iter().map(|&d| cl[d].clone()).collect();
    timeline::simulate(&plan, &sub, &CommConfig::default(), &m)
        .ok()
        .map(|t| t.total_s)
}

fn run(policy: &dyn GangPolicy, rate: f64, n: usize) -> GangSimStats {
    simulate_gang_policy(rate, n, &speeds(), policy, &latency_of, 42)
}

/// The acceptance criterion: on a 4-GPU heterogeneous cluster under
/// load (>= 2 requests in flight), the adaptive gang policy clears
/// strictly more throughput than the whole-cluster baseline, while
/// AllGpus keeps the lowest single-request latency — and every lease
/// granted along the way is pairwise disjoint.
#[test]
fn adaptive_beats_allgpus_on_throughput_not_single_latency() {
    // Single-request latency per policy: one request on an idle fleet.
    let single_all = run(&AllGpus, 1.0, 1).mean_service_s;
    let single_adaptive = run(&Adaptive::default(), 1.0, 1).mean_service_s;
    let single_fixed = run(&FixedGang(2), 1.0, 1).mean_service_s;
    assert!(single_all > 0.0);
    // STADI absorbs the stragglers, so the full gang is the fastest
    // way to serve one request; the adaptive policy's min-latency
    // search finds the same gang (tie), fixed:2 is strictly slower.
    assert!(
        single_all <= single_adaptive + 1e-9,
        "AllGpus {single_all} vs adaptive {single_adaptive}"
    );
    assert!(
        single_all < single_fixed - 1e-9,
        "AllGpus {single_all} vs fixed:2 {single_fixed}"
    );

    // Under ~2x AllGpus capacity, the queue builds and the adaptive
    // policy shards the fleet into concurrent gangs.
    let rate = 2.0 / single_all;
    let n = 120;
    let all = run(&AllGpus, rate, n);
    let adaptive = run(&Adaptive::default(), rate, n);
    assert_eq!(all.completed, n);
    assert_eq!(adaptive.completed, n);
    assert!(
        adaptive.max_in_flight >= 2,
        "adaptive never overlapped requests (max_in_flight {})",
        adaptive.max_in_flight
    );
    assert!(all.max_in_flight == 1, "AllGpus must serialize the fleet");
    assert!(
        adaptive.throughput_rps > all.throughput_rps,
        "adaptive {} rps <= AllGpus {} rps",
        adaptive.throughput_rps,
        all.throughput_rps
    );
    // Per-request service time is the price of sharding: AllGpus stays
    // the latency king even under load.
    assert!(all.mean_service_s <= adaptive.mean_service_s + 1e-9);

    // Disjointness audit over every granted lease, and the adaptive
    // run must actually have had time-overlapping leases to audit.
    let all_checked = assert_leases_disjoint(&all.leases);
    assert_eq!(all_checked, 0, "whole-cluster leases cannot overlap");
    let adaptive_checked = assert_leases_disjoint(&adaptive.leases);
    assert!(
        adaptive_checked > 0,
        "adaptive run produced no concurrent leases to audit"
    );
}

/// Sharding helps because smaller gangs pay less sync/straggler
/// overhead per request than their share of the fleet: two disjoint
/// 2-gangs outrun one serialized 4-gang.
#[test]
fn fixed_small_gangs_raise_throughput_under_load() {
    let single_all = run(&AllGpus, 1.0, 1).mean_service_s;
    let rate = 2.0 / single_all;
    let all = run(&AllGpus, rate, 100);
    let duo = run(&FixedGang(2), rate, 100);
    assert!(
        duo.throughput_rps > all.throughput_rps,
        "fixed:2 {} <= all {}",
        duo.throughput_rps,
        all.throughput_rps
    );
    assert!(duo.max_in_flight >= 2);
    assert_leases_disjoint(&duo.leases);
}

/// Low arrival rate: the adaptive policy behaves like AllGpus (same
/// min-latency gang), so it never pays the sharding latency tax when
/// there is no queue to clear.
#[test]
fn adaptive_matches_allgpus_when_idle() {
    let all = run(&AllGpus, 0.1, 20);
    let adaptive = run(&Adaptive::default(), 0.1, 20);
    assert!(
        (adaptive.mean_service_s - all.mean_service_s).abs() < 1e-9,
        "idle adaptive {} vs all {}",
        adaptive.mean_service_s,
        all.mean_service_s
    );
    assert!((adaptive.mean_gang_size - 4.0).abs() < 1e-9);
}

/// More devices help a single request on this cluster (the premise
/// behind AllGpus being the latency-optimal policy above) — pin it so
/// a cost-model change that silently breaks the premise fails here,
/// not in the throughput assertions.
#[test]
fn full_gang_is_single_request_latency_optimal() {
    let full = latency_of(&[0, 1, 2, 3]).unwrap();
    for gang in [
        vec![0],
        vec![0, 1],
        vec![0, 1, 2],
        vec![0, 3],
        vec![1, 2],
    ] {
        let t = latency_of(&gang).unwrap();
        assert!(
            full <= t + 1e-9,
            "gang {gang:?} ({t}s) beat the full fleet ({full}s)"
        );
    }
}
