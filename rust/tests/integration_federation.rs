//! Federated serving, end to end on the stub runtime — runs on every
//! build (no artifacts, no xla feature needed).
//!
//! Pins the PR's acceptance criteria:
//!
//! * equal-speed migration is a numerics no-op: run a request to the
//!   mid-plan barrier on one node, ship the envelope, finish on a
//!   sibling of identical speeds — the latent is **byte-identical**
//!   to an uninterrupted single-node run;
//! * spill-over admission never touches a saturated home's grant
//!   ledger: the home answers busy without granting, the sibling
//!   grants, and `granted_total` proves which is which;
//! * the scaled DES frontier: on every trace, at every load at or
//!   past 2x one node's capacity, federated+migration strictly beats
//!   both migration-off federation and the single-node baseline on
//!   deadline hits — and the committed `BENCH_federation.json`
//!   matches the in-process sweep field for field at 1e-9;
//! * the default config (`nodes: 1`, `migrate: false`) is the
//!   pre-federation path bit-exact, and a 1-node tier serves exactly
//!   what the bare core serves;
//! * the same envelope seam re-admits an excluded device *within* a
//!   node: a device pinned out by Eq. 4 at plan time joins the suffix
//!   after its occupancy clears, which the stock mid-flight re-planner
//!   (by contract) never does.

use std::path::{Path, PathBuf};

use stadi::config::{EngineConfig, FederationConfig, StadiParams};
use stadi::coordinator::EngineCore;
use stadi::federation::{resume_envelope_on, FrontTier, MigrationEnvelope};
use stadi::serve::sim::{
    simulate_federation_frontier, FederationSimConfig,
};
use stadi::spec::GenerationSpec;
use stadi::util::json::{self, Value};

/// Write a fresh stub artifact set into a per-test temp dir.
fn stub_artifacts(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("stadi-fed-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    stadi::runtime::stubgen::write_stub_artifacts(
        &dir,
        stadi::runtime::stubgen::DEFAULT_EXTRA_RESOLUTIONS,
    )
    .unwrap();
    dir
}

fn config(dir: &Path, occ: &[f64]) -> EngineConfig {
    let mut cfg = EngineConfig::two_gpu_default(dir, occ);
    cfg.stadi =
        StadiParams { m_base: 6, m_warmup: 2, ..Default::default() };
    cfg
}

#[test]
fn equal_speed_migration_latent_is_byte_identical() {
    let dir = stub_artifacts("mig");
    let mut cfg = config(&dir, &[0.0, 0.0]);
    cfg.federation = FederationConfig {
        nodes: 2,
        migrate: true,
        ..Default::default()
    };
    let tier = FrontTier::homogeneous(&cfg).unwrap();
    let spec = GenerationSpec::new().seed(11);

    // Uninterrupted baseline on an independent core (no shared plan
    // cache, no shared profiler — same config, fresh state).
    let mut solo_cfg = cfg.clone();
    solo_cfg.federation = FederationConfig::default();
    let solo_core = EngineCore::new(solo_cfg).unwrap();
    let baseline =
        solo_core.session_for(&spec).unwrap().execute(&spec).unwrap();

    let total = tier
        .node(0)
        .core()
        .session_for(&spec)
        .unwrap()
        .plan()
        .sync_points
        .len();
    assert!(total >= 2, "fixture must have interior barriers");
    for n_syncs in 1..total {
        let g = tier.generate_migrated(&spec, n_syncs, 0, 1).unwrap();
        assert_eq!(
            g.latent, baseline.latent,
            "migration at barrier {n_syncs}/{total} must not change \
             a single byte of the latent"
        );
        // The handoff charges the envelope transfer on the resumed
        // clock: at equal speeds the migrated timeline can never beat
        // the uninterrupted one.
        assert!(g.timeline.total_s >= baseline.timeline.total_s - 1e-12);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn envelope_json_roundtrip_resumes_identically() {
    let dir = stub_artifacts("env");
    let mut cfg = config(&dir, &[0.0, 0.0]);
    cfg.federation = FederationConfig {
        nodes: 2,
        migrate: true,
        ..Default::default()
    };
    let tier = FrontTier::homogeneous(&cfg).unwrap();
    let spec = GenerationSpec::new().seed(23);
    let session = tier.node(0).core().session_for(&spec).unwrap();
    let total = session.plan().sync_points.len();
    let ckpt = session.execute_to_barrier(spec.seed, total / 2).unwrap();
    let env = MigrationEnvelope::capture(&session, &ckpt, spec.seed)
        .unwrap()
        .expect("mid-plan barrier leaves a migratable suffix");

    // Wire round-trip: serialize, re-parse, resume on the sibling.
    let wire = json::to_string(&env.to_json());
    let decoded =
        MigrationEnvelope::from_json(&json::parse(&wire).unwrap())
            .unwrap();
    let direct = tier.resume_on(1, &env).unwrap().expect("no deferral");
    let roundtrip =
        tier.resume_on(1, &decoded).unwrap().expect("no deferral");
    assert_eq!(direct.latent, roundtrip.latent);
    assert_eq!(direct.timeline.total_s, roundtrip.timeline.total_s);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn spillover_leaves_saturated_home_ledger_untouched() {
    let dir = stub_artifacts("spill");
    let mut cfg = config(&dir, &[0.0, 0.0]);
    cfg.federation = FederationConfig {
        nodes: 2,
        shard_policy: "hash".to_string(),
        ..Default::default()
    };
    let tier = FrontTier::homogeneous(&cfg).unwrap();
    let spec = GenerationSpec::new().seed(5);
    let home = tier.route(&spec);
    let sibling = 1 - home;

    // Saturate the home node by holding its whole-fleet lease.
    let held = tier
        .node(home)
        .try_admit()
        .unwrap()
        .expect("idle home must grant");
    let home_granted = tier.node(home).fleet().granted_total();
    let sib_granted = tier.node(sibling).fleet().granted_total();

    let (id, lease) = tier
        .admit(&spec)
        .unwrap()
        .expect("sibling has capacity, admission must spill");
    assert_eq!(id, sibling, "grant must come from the spill target");
    assert_eq!(
        tier.node(home).fleet().granted_total(),
        home_granted,
        "a busy home answers busy without granting"
    );
    assert_eq!(
        tier.node(sibling).fleet().granted_total(),
        sib_granted + 1
    );

    // Both nodes saturated: admission yields None and no ledger moves.
    let home_granted = tier.node(home).fleet().granted_total();
    let sib_granted = tier.node(sibling).fleet().granted_total();
    assert!(tier.admit(&spec).unwrap().is_none());
    assert_eq!(tier.node(home).fleet().granted_total(), home_granted);
    assert_eq!(
        tier.node(sibling).fleet().granted_total(),
        sib_granted
    );

    drop(lease);
    drop(held);
    assert_eq!(tier.node(home).fleet().in_flight(), 0);
    assert_eq!(tier.node(sibling).fleet().in_flight(), 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Recursive 1e-9 comparison of two JSON values (same shape, same
/// strings, numbers within tolerance).
fn assert_json_close(a: &Value, b: &Value, path: &str) {
    match (a, b) {
        (Value::Num(x), Value::Num(y)) => {
            assert!(
                (x - y).abs() <= 1e-9,
                "{path}: {x} vs {y} differ by more than 1e-9"
            );
        }
        (Value::Str(x), Value::Str(y)) => {
            assert_eq!(x, y, "{path}: string mismatch");
        }
        (Value::Bool(x), Value::Bool(y)) => {
            assert_eq!(x, y, "{path}: bool mismatch");
        }
        (Value::Null, Value::Null) => {}
        (Value::Arr(xs), Value::Arr(ys)) => {
            assert_eq!(xs.len(), ys.len(), "{path}: length mismatch");
            for (i, (x, y)) in xs.iter().zip(ys).enumerate() {
                assert_json_close(x, y, &format!("{path}[{i}]"));
            }
        }
        (Value::Obj(xo), Value::Obj(yo)) => {
            assert_eq!(xo.len(), yo.len(), "{path}: key-count mismatch");
            for (k, x) in xo.iter() {
                let y = yo
                    .get(k)
                    .unwrap_or_else(|| panic!("{path}.{k}: missing"));
                assert_json_close(x, y, &format!("{path}.{k}"));
            }
        }
        _ => panic!("{path}: shape mismatch"),
    }
}

#[test]
fn federation_frontier_matches_committed_bench() {
    let sweep =
        simulate_federation_frontier(&FederationSimConfig::stub_fixture());
    // Strict win at every >= 2x point, on every trace — the tentpole
    // claim the committed artifact makes.
    for tr in &sweep.traces {
        let mut asserted = 0usize;
        for p in &tr.points {
            if p.load_x < 2.0 {
                continue;
            }
            asserted += 1;
            assert!(
                p.fed_mig.deadline_hit_rate
                    > p.fed_nomig.deadline_hit_rate,
                "{} x{}: migration must strictly win",
                tr.trace,
                p.load_x
            );
            assert!(
                p.fed_nomig.deadline_hit_rate
                    > p.single.deadline_hit_rate,
                "{} x{}: federation must strictly win",
                tr.trace,
                p.load_x
            );
            assert!(p.fed_mig.migrations > 0);
        }
        assert!(asserted >= 2, "{}: sweep must reach 2x", tr.trace);
    }

    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap()
        .join("BENCH_federation.json");
    let committed = json::from_file(&path).unwrap_or_else(|e| {
        panic!(
            "BENCH_federation.json must be committed at the repo root \
             (regenerate with scripts/gen_bench_artifacts.py): {e}"
        )
    });
    assert_json_close(&sweep.to_json(), &committed, "BENCH_federation");
}

#[test]
fn default_config_is_pre_federation_bit_exact() {
    let dir = stub_artifacts("default");
    let cfg = config(&dir, &[0.0, 0.4]);
    assert_eq!(cfg.federation, FederationConfig::default());
    assert_eq!(cfg.federation.nodes, 1);
    assert!(!cfg.federation.migrate);

    let core = EngineCore::new(cfg.clone()).unwrap();
    let spec = GenerationSpec::new().seed(42);
    let bare =
        core.session_for(&spec).unwrap().execute(&spec).unwrap();

    // A 1-node tier is an admission wrapper around the same engine:
    // identical latent, identical simulated timeline.
    let tier = FrontTier::homogeneous(&cfg).unwrap();
    assert_eq!(tier.num_nodes(), 1);
    assert!(!tier.migrate_enabled());
    let (id, federated) = tier.generate(&spec).unwrap();
    assert_eq!(id, 0);
    assert_eq!(federated.latent, bare.latent);
    assert_eq!(federated.timeline.total_s, bare.timeline.total_s);

    // Migration entry points refuse when the config bit is off.
    let err = tier
        .generate_migrated(&spec, 1, 0, 0)
        .expect_err("migrate: false must refuse the migration driver");
    assert!(err.to_string().contains("disabled"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Regression for the parity-deferral fallback: when the migration
/// destination declines at the barrier (`Ok(None)` — a Half-class
/// continuation needs an odd suffix), the source must be able to
/// finish locally from the *same* envelope, byte-identical to an
/// uninterrupted run. Nothing about the declined handoff may leak
/// into the fallback numerics.
#[test]
fn parity_deferral_resumes_locally_from_same_envelope() {
    let dir = stub_artifacts("defer");
    let cfg = config(&dir, &[0.0, 0.0]);
    let spec = GenerationSpec::new().seed(77);

    // Uninterrupted baseline on an independent core (fresh profiler,
    // fresh plan cache — same config).
    let baseline = EngineCore::new(cfg.clone())
        .unwrap()
        .session_for(&spec)
        .unwrap()
        .execute(&spec)
        .unwrap();

    let core = EngineCore::new(cfg).unwrap();
    let session = core.session_for(&spec).unwrap();
    let total = session.plan().sync_points.len();
    // Pick a barrier whose remaining fast suffix is even — the parity
    // a Half-class destination must decline.
    let (n_syncs, env) = (1..total)
        .find_map(|k| {
            let ckpt =
                session.execute_to_barrier(spec.seed, k).unwrap();
            MigrationEnvelope::capture(&session, &ckpt, spec.seed)
                .unwrap()
                .filter(|e| e.fast_suffix.len() % 2 == 0)
                .map(|e| (k, e))
        })
        .expect("fixture must reach an even-suffix barrier");

    // Destination with a Half-class sibling (0.5 <= 0.75 * v_max, yet
    // above the Eq. 4 exclusion floor): must defer, not error.
    let deferred = resume_envelope_on(&core, &env, &[1.0, 0.5]).unwrap();
    assert!(
        deferred.is_none(),
        "half-class destination must defer the even suffix \
         (barrier {n_syncs}, suffix {})",
        env.fast_suffix.len()
    );

    // Fallback: the source finishes locally from the very same
    // envelope bytes.
    let local = resume_envelope_on(&core, &env, &[1.0, 1.0])
        .unwrap()
        .expect("full-speed local resume never defers");
    assert_eq!(
        local.latent, baseline.latent,
        "declined migration must fall back to a byte-identical \
         local finish"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn excluded_device_rejoins_suffix_after_occupancy_clears() {
    let dir = stub_artifacts("readmit");
    // occ 0.8 -> effective speed 0.2 <= b * v_max (0.25): gpu1 is
    // excluded by Eq. 4 at plan time.
    let cfg = config(&dir, &[0.0, 0.8]);
    let core = EngineCore::new(cfg).unwrap();
    let spec = GenerationSpec::new().seed(31);
    let session = core.session_for(&spec).unwrap();
    let plan = session.plan();
    let included: Vec<usize> =
        plan.included_devices().map(|d| d.device).collect();
    assert_eq!(
        included,
        vec![0],
        "fixture must start with gpu1 excluded"
    );

    let total = plan.sync_points.len();
    let ckpt = session.execute_to_barrier(spec.seed, total / 2).unwrap();
    let env = MigrationEnvelope::capture(&session, &ckpt, spec.seed)
        .unwrap()
        .expect("interior barrier leaves a suffix");

    // gpu1's occupancy cleared: resume the envelope on the same node
    // with explicit live speeds. The suffix re-plan sees fully-fresh
    // barrier state, so the recovered device is included — the stock
    // mid-flight re-planner would have pinned it out forever.
    let g = resume_envelope_on(&core, &env, &[1.0, 1.0])
        .unwrap()
        .expect("even suffix must not defer");
    assert!(
        g.stats.steps_run[1] > 0,
        "re-admitted gpu1 must run suffix steps, got {:?}",
        g.stats.steps_run
    );
    assert!(g.stats.steps_run[0] > 0);
    let _ = std::fs::remove_dir_all(&dir);
}
