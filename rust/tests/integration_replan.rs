//! Mid-flight re-planning, end to end on the stub runtime — runs on
//! every build. These tests pin the PR's acceptance criteria:
//!
//! * a zero-drift re-plan is byte-identical to the static plan
//!   (latents AND virtual timeline), and the `replan.enabled = false`
//!   flag restores the frozen-plan (PR-4) behavior exactly, drift
//!   table present or not;
//! * a deterministically injected mid-run drift (stub-manifest
//!   `"drift"` table) triggers in-request re-plans that migrate rows
//!   and strictly reduce the virtual makespan vs the frozen plan
//!   replayed under the same drift;
//! * drift detection on a lease-restricted session goes through the
//!   local→global device map: drift on the session's *own* global
//!   devices re-plans, drift on devices outside the lease never does
//!   (the profiler feedback round-trip audit);
//! * the DES drift comparison serializes byte-identically — the CI
//!   flake gate (`scripts/check.sh`) runs these tests twice and diffs
//!   the stats JSON written via `STADI_REPLAN_STATS_OUT`.

use std::path::{Path, PathBuf};

use stadi::config::{
    DeviceConfig, EngineConfig, ExecMode, ReplanConfig, StadiParams,
};
use stadi::coordinator::{timeline, EngineCore};
use stadi::device::OccupancySchedule;
use stadi::runtime::stubgen;
use stadi::spec::GenerationSpec;

/// Write a fresh stub artifact set with an optional drift table into a
/// per-test temp dir.
fn stub_artifacts(tag: &str, drift: Option<&str>) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("stadi-replan-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let sched = drift.map(|s| OccupancySchedule::parse(s).unwrap());
    stubgen::write_stub_artifacts_with_drift(&dir, &[], sched.as_ref())
        .unwrap();
    dir
}

fn config(dir: &Path, occ: &[f64]) -> EngineConfig {
    let mut cfg = EngineConfig::two_gpu_default(dir, occ);
    cfg.stadi = StadiParams { m_base: 16, m_warmup: 2, ..Default::default() };
    cfg
}

fn enable_replan(cfg: &mut EngineConfig, k: usize, threshold: f64) {
    cfg.replan = ReplanConfig {
        enabled: true,
        every_k_syncs: k,
        drift_threshold: threshold,
    };
}

/// Acceptance criterion 1: with a constant (zero-drift) schedule the
/// adaptive loop must reproduce the frozen path byte for byte — same
/// latents, same virtual timeline, no re-plan events — even at
/// threshold 0 where every barrier re-evaluates.
#[test]
fn zero_drift_replan_is_byte_identical_to_the_static_plan() {
    // The drift table pins both devices at their config occupancy, so
    // the virtual measurements equal the plan's speed snapshot exactly
    // and every re-plan evaluation is a structural no-op.
    let dir = stub_artifacts("zerodrift", Some("0@0;0.4@0"));
    let spec = GenerationSpec::new().seed(11);

    let frozen = EngineCore::new(config(&dir, &[0.0, 0.4]))
        .unwrap()
        .generate(&spec)
        .unwrap();
    let mut cfg = config(&dir, &[0.0, 0.4]);
    enable_replan(&mut cfg, 2, 0.0);
    let adaptive = EngineCore::new(cfg).unwrap().generate(&spec).unwrap();

    assert_eq!(
        frozen.latent, adaptive.latent,
        "zero-drift adaptive execution diverged from the static plan"
    );
    assert!(adaptive.replans.is_empty(), "{:?}", adaptive.replans);
    // The virtual timeline is the same arithmetic, merely segmented.
    assert_eq!(frozen.timeline.total_s, adaptive.timeline.total_s);
    assert_eq!(frozen.timeline.busy_s, adaptive.timeline.busy_s);
    assert_eq!(frozen.timeline.comm_s, adaptive.timeline.comm_s);
    assert_eq!(frozen.stats.steps_run, adaptive.stats.steps_run);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The disabled flag restores PR-4 behavior exactly: a drift table in
/// the manifest changes *nothing* on the frozen path — identical
/// latents and identical (drift-blind) timeline vs a plain artifact
/// set.
#[test]
fn replan_disabled_ignores_drift_entirely() {
    let plain = stub_artifacts("plain", None);
    let drifted = stub_artifacts("drifted", Some("0@0;0@0,0.7@4"));
    let spec = GenerationSpec::new().seed(7);

    let a = EngineCore::new(config(&plain, &[0.0, 0.0]))
        .unwrap()
        .generate(&spec)
        .unwrap();
    let b = EngineCore::new(config(&drifted, &[0.0, 0.0]))
        .unwrap()
        .generate(&spec)
        .unwrap();
    assert_eq!(a.latent, b.latent);
    assert_eq!(a.timeline.total_s, b.timeline.total_s);
    assert!(b.replans.is_empty());
    let _ = std::fs::remove_dir_all(&plain);
    let _ = std::fs::remove_dir_all(&drifted);
}

/// Acceptance criterion 2: an injected mid-run drift (device 1 drops
/// to 30% speed at its 4th step) triggers a re-plan that demotes and
/// shrinks the straggler, migrates rows, and strictly beats the
/// frozen plan's makespan under the *same* drift — deterministically,
/// on any build, across executors.
#[test]
fn injected_drift_replans_and_strictly_beats_the_frozen_makespan() {
    let dir = stub_artifacts("ramp", Some("0@0;0@0,0.7@4"));
    let spec = GenerationSpec::new().seed(21);
    let run = |mode: ExecMode| {
        let mut cfg = config(&dir, &[0.0, 0.0]);
        cfg.mode = mode;
        enable_replan(&mut cfg, 2, 0.1);
        EngineCore::new(cfg).unwrap().generate(&spec).unwrap()
    };

    let g = run(ExecMode::Dataflow);
    assert!(!g.replans.is_empty(), "ramp did not trigger a re-plan");
    let ev = &g.replans[0];
    assert!(ev.migrated_rows > 0, "re-plan moved no rows");
    assert!(ev.migration_bytes > 0);
    assert!(ev.classes_changed, "straggler was not demoted");
    assert!(
        ev.live_speeds[1] < 0.5,
        "live speed missed the drift: {:?}",
        ev.live_speeds
    );

    // Frozen baseline: the same initial plan replayed under the same
    // drift schedule (the timeline model the paper's figures use).
    let core = EngineCore::new(config(&dir, &[0.0, 0.0])).unwrap();
    let sched = OccupancySchedule::parse("0@0;0@0,0.7@4").unwrap();
    let frozen = timeline::simulate_under_drift(
        &g.plan,
        &core.cluster(),
        &core.config().comm,
        &core.exec().manifest().model,
        &sched,
        &[0, 1],
    )
    .unwrap();
    assert!(
        g.timeline.total_s < frozen.total_s,
        "mid-flight {} should strictly beat frozen {}",
        g.timeline.total_s,
        frozen.total_s
    );

    // Determinism: a fresh engine reproduces the run bit for bit
    // (latents, events, virtual clock) — wall time never leaks in.
    let h = run(ExecMode::Dataflow);
    assert_eq!(g.latent, h.latent, "adaptive run not deterministic");
    assert_eq!(g.replans.len(), h.replans.len());
    assert_eq!(g.timeline.total_s, h.timeline.total_s);
    assert_eq!(
        g.replans[0].migrated_rows,
        h.replans[0].migrated_rows
    );

    // Cross-executor pin: the threaded executor runs the same adaptive
    // path (segments, migrations and all) with bit-equal numerics.
    let th = run(ExecMode::Threaded);
    assert_eq!(
        g.latent, th.latent,
        "threaded and dataflow adaptive numerics diverge"
    );
    assert_eq!(g.replans.len(), th.replans.len());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite: the local→global map round-trip on a restricted lease.
/// Drift on the session's own global device must re-plan; drift on a
/// device *outside* the lease must not (a session indexing the fleet
/// schedule by its local ids would invert both answers). Profiler
/// feedback stays keyed by global ids throughout.
#[test]
fn lease_restricted_replan_keys_drift_by_global_device_id() {
    let three = |dir: &Path| {
        let cfg = EngineConfig {
            artifacts_dir: dir.to_path_buf(),
            devices: vec![
                DeviceConfig::new("gpu0", 1.0, 0.0),
                DeviceConfig::new("gpu1", 1.0, 0.0),
                DeviceConfig::new("gpu2", 1.0, 0.0),
            ],
            stadi: StadiParams {
                m_base: 16,
                m_warmup: 2,
                ..Default::default()
            },
            comm: Default::default(),
            mode: ExecMode::Dataflow,
            replan: ReplanConfig {
                enabled: true,
                every_k_syncs: 2,
                drift_threshold: 0.1,
            },
            halo: Default::default(),
            batch: Default::default(),
            federation: Default::default(),
        };
        cfg.validate().unwrap();
        cfg
    };
    let spec = GenerationSpec::new().seed(5);

    // Case A: global device 2 drifts — it is local index 1 of the
    // [1, 2] lease, so the session must react.
    let dir = stub_artifacts("lease-own", Some(";;0@0,0.7@4"));
    let core = EngineCore::new(three(&dir)).unwrap();
    let fleet = core.fleet();
    let lease = fleet.try_acquire(&[1, 2]).unwrap().unwrap();
    let session = core.session_for_on(&spec, &lease).unwrap();
    assert_eq!(session.devices(), &[1, 2]);
    let g = session.execute(&spec).unwrap();
    assert!(
        !g.replans.is_empty(),
        "drift on a leased device (global 2) was not detected"
    );
    assert!(
        g.replans[0].live_speeds[1] < 0.5,
        "drift must land on local index 1 (global 2): {:?}",
        g.replans[0].live_speeds
    );
    // Feedback landed under global ids: the 3-wide speed vector is
    // intact and a whole-cluster plan still works.
    assert_eq!(core.effective_speeds().len(), 3);
    core.session().unwrap();
    drop(lease);
    let _ = std::fs::remove_dir_all(&dir);

    // Case C: global device 0 drifts — it is outside the [1, 2]
    // lease. A session wrongly indexing the schedule by *local* ids
    // would see "device 0" drift and re-plan; the correct session
    // never does.
    let dir = stub_artifacts("lease-other", Some("0@0,0.7@4;;"));
    let core = EngineCore::new(three(&dir)).unwrap();
    let fleet = core.fleet();
    let lease = fleet.try_acquire(&[1, 2]).unwrap().unwrap();
    let g = core
        .session_for_on(&spec, &lease)
        .unwrap()
        .execute(&spec)
        .unwrap();
    assert!(
        g.replans.is_empty(),
        "drift outside the lease triggered a re-plan: {:?}",
        g.replans
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Flake gate: the DES drift comparison is a pure function of the
/// scenario. `scripts/check.sh` runs this test twice in one job with
/// `STADI_REPLAN_STATS_OUT` pointing at two different files and
/// `diff`s them — any nondeterminism (wall-clock leakage, map-order
/// iteration, uninitialized state) fails CI without a single retry.
#[test]
fn drift_stats_json_is_pinned_and_midflight_wins() {
    let schedule =
        stadi::model::schedule::Schedule::scaled_linear(1000, 0.00085, 0.012);
    let params =
        StadiParams { m_base: 16, m_warmup: 2, ..Default::default() };
    let devices = vec![
        DeviceConfig::new("g0", 1.0, 0.0),
        DeviceConfig::new("g1", 1.0, 0.0),
    ];
    let cost = stadi::device::CostModel { fixed_s: 0.004, per_row_s: 0.0012 };
    let comm = stadi::config::CommConfig::default();
    let model = stadi::runtime::artifacts::ModelInfo {
        latent_h: 32,
        latent_w: 32,
        latent_c: 4,
        patch: 2,
        dim: 96,
        heads: 4,
        layers: 3,
        temb_dim: 64,
        row_granularity: 4,
        tokens_full: 256,
        param_count: 1,
        params_seed: 0,
    };
    let scenario = stadi::serve::sim::DriftScenario {
        requests: 3,
        drift: OccupancySchedule::parse("0@0;0@0,0.7@6").unwrap(),
        replan: ReplanConfig {
            enabled: true,
            every_k_syncs: 2,
            drift_threshold: 0.1,
        },
    };
    let cmp = stadi::serve::sim::simulate_drift_strategies(
        &schedule, &params, &devices, cost, &comm, &model, &scenario,
    )
    .unwrap();
    assert!(cmp.midflight.total_s < cmp.ewma.total_s);
    assert!(cmp.ewma.total_s < cmp.frozen.total_s);
    assert!(cmp.midflight.replans >= 1);
    let json = stadi::util::json::to_string_pretty(&cmp.to_json());
    // In-process determinism (the cross-process pin is the CI diff).
    let again = stadi::serve::sim::simulate_drift_strategies(
        &schedule, &params, &devices, cost, &comm, &model, &scenario,
    )
    .unwrap();
    assert_eq!(
        json,
        stadi::util::json::to_string_pretty(&again.to_json())
    );
    if let Ok(path) = std::env::var("STADI_REPLAN_STATS_OUT") {
        if !path.trim().is_empty() {
            std::fs::write(&path, &json).unwrap();
        }
    }
}
