//! End-to-end serving tests: real TCP sockets, real engine, real
//! artifacts — python nowhere on the path.
//!
//! Topology note: the server (and thus the engine + PJRT service) runs
//! on the libtest thread and the client is the spawned thread. The
//! inverted topology (engine constructed on the libtest thread, serve
//! on a spawned thread) deterministically deadlocks inside
//! xla_extension's compile thread pool under the libtest harness —
//! same code runs fine as a standalone binary (see
//! examples/serve_workload.rs, which exercises exactly that shape).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;
use std::thread;

use stadi::config::{EngineConfig, StadiParams};
use stadi::coordinator::Engine;
use stadi::serve::server::{serve, Client};
use stadi::util::json;

fn config() -> Option<EngineConfig> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let mut cfg = EngineConfig::two_gpu_default(dir, &[0.0, 0.4]);
    cfg.stadi = StadiParams { m_base: 6, m_warmup: 2, ..Default::default() };
    Some(cfg)
}

#[test]
fn serves_requests_over_tcp() {
    let Some(cfg) = config() else { return };
    let mut engine = Engine::new(cfg).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();

    let client_thread = thread::spawn(move || {
        let mut client = Client::connect(&addr).unwrap();
        let mut sums = Vec::new();
        for i in 0..3 {
            let line = client
                .request(&format!("r{i}"), 100 + i as u64)
                .unwrap();
            let v = json::parse(&line).unwrap();
            assert!(v.get("ok").unwrap().as_bool().unwrap(), "{line}");
            assert_eq!(
                v.get("id").unwrap().as_str().unwrap(),
                format!("r{i}")
            );
            assert!(v.get("latency_s").unwrap().as_f64().unwrap() > 0.0);
            assert!(
                v.get("sim_latency_s").unwrap().as_f64().unwrap() > 0.0
            );
            let plan = v.get("plan").unwrap();
            assert!(
                plan.get("gpu0")
                    .unwrap()
                    .get("rows")
                    .unwrap()
                    .as_usize()
                    .unwrap()
                    > 0,
                "{line}"
            );
            sums.push(v.get("latent_sum").unwrap().as_f64().unwrap());
        }
        sums
    });

    let handled = serve(&mut engine, listener, 8, 3, None).unwrap();
    let sums = client_thread.join().unwrap();
    assert_eq!(handled, 3);
    // Distinct seeds -> distinct images. (Same-seed determinism needs a
    // pinned plan — the profiler legitimately replans between requests —
    // and is covered by engine::tests::same_seed_same_plan_same_image.)
    assert!((sums[0] - sums[1]).abs() > 1e-6);
    assert!((sums[1] - sums[2]).abs() > 1e-6);
}

#[test]
fn malformed_requests_get_error_responses() {
    let Some(cfg) = config() else { return };
    let mut engine = Engine::new(cfg).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();

    let client_thread = thread::spawn(move || {
        let mut stream = std::net::TcpStream::connect(&addr).unwrap();
        writeln!(stream, "this is not json").unwrap();
        writeln!(stream, "{{\"id\": \"ok1\", \"seed\": 5}}").unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = json::parse(line.trim()).unwrap();
        assert!(!v.get("ok").unwrap().as_bool().unwrap());
        line.clear();
        reader.read_line(&mut line).unwrap();
        let v = json::parse(line.trim()).unwrap();
        assert!(v.get("ok").unwrap().as_bool().unwrap());
    });

    serve(&mut engine, listener, 8, 1, None).unwrap();
    client_thread.join().unwrap();
}
