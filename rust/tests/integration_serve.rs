//! End-to-end serving tests.
//!
//! The serving machinery (accept loop, worker pool, router
//! backpressure, per-connection response ordering, shutdown) is
//! exercised against a stub `JobRunner`, so those tests run on a bare
//! toolchain with no artifacts. The real-engine tests (marked below)
//! need built artifacts + the xla backend and skip otherwise.
//!
//! Topology note for the real-engine tests: the core (and thus the
//! PJRT service) is constructed on the libtest thread and `serve` runs
//! there too, with clients on spawned threads. The inverted topology
//! (core constructed on the libtest thread, serve on a spawned thread)
//! deterministically deadlocks inside xla_extension's compile thread
//! pool under the libtest harness — same code runs fine as a
//! standalone binary (see examples/serve_workload.rs, which exercises
//! exactly that shape).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::Duration;

use stadi::config::{EngineConfig, StadiParams};
use stadi::coordinator::EngineCore;
use stadi::fleet::FleetManager;
use stadi::serve::router::Job;
use stadi::serve::server::{
    serve, serve_with, serve_with_stats, Client, JobRunner, ServeOptions,
};
use stadi::spec::{GenerationSpec, Priority};
use stadi::util::json;

/// Stub executor: per-job delay varying with the seed so concurrent
/// workers finish out of submission order, which is exactly what the
/// per-connection reorder buffer must hide.
struct StubRunner {
    delay_ms: u64,
}

impl JobRunner for StubRunner {
    fn run(&self, job: &Job) -> (bool, String) {
        if self.delay_ms > 0 {
            let d = self.delay_ms + (job.seed() % 3) * self.delay_ms;
            thread::sleep(Duration::from_millis(d));
        }
        (
            true,
            format!(
                "{{\"id\": \"{}\", \"ok\": true, \"seed\": {}}}",
                job.id, job.seed()
            ),
        )
    }
}

fn opts(queue: usize, workers: usize, max: usize) -> ServeOptions {
    ServeOptions {
        queue_capacity: queue,
        workers,
        max_requests: max,
        ..ServeOptions::default()
    }
}

/// Regression test for the shutdown bug: the old server only checked
/// `stop` between connections, so with no inbound connection a set
/// flag never interrupted the blocking accept. The nonblocking accept
/// loop must exit promptly with zero clients.
#[test]
fn stop_flag_interrupts_idle_server() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let (tx, rx) = mpsc::channel();
    {
        let stop = Arc::clone(&stop);
        thread::spawn(move || {
            let r = serve_with(
                Arc::new(StubRunner { delay_ms: 0 }),
                listener,
                ServeOptions::default(),
                Some(stop),
            );
            let _ = tx.send(r);
        });
    }
    // Let the server reach its accept loop, then flip the flag —
    // crucially without ever connecting.
    thread::sleep(Duration::from_millis(50));
    stop.store(true, Ordering::SeqCst);
    let r = rx
        .recv_timeout(Duration::from_secs(5))
        .expect("server did not exit after stop flag was set");
    assert_eq!(r.unwrap(), 0);
}

/// Four concurrent TCP clients, each pipelining several requests:
/// everyone gets all responses, in per-connection FIFO order, while
/// the worker pool completes jobs out of order.
#[test]
fn four_concurrent_clients_fifo_per_connection() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let server = {
        let stop = Arc::clone(&stop);
        thread::spawn(move || {
            serve_with(
                Arc::new(StubRunner { delay_ms: 5 }),
                listener,
                opts(64, 3, 0),
                Some(stop),
            )
        })
    };

    let per_client = 6usize;
    let clients: Vec<_> = (0..4usize)
        .map(|c| {
            let addr = addr.clone();
            thread::spawn(move || {
                let mut client = Client::connect(&addr).unwrap();
                // Pipeline everything first: with 3 workers and
                // seed-dependent delays, completion order scrambles.
                for i in 0..per_client {
                    client
                        .send(
                            &format!("c{c}-{i}"),
                            (c * 17 + i * 5 + i) as u64,
                        )
                        .unwrap();
                }
                let mut ids = Vec::new();
                for _ in 0..per_client {
                    let line = client.read_line().unwrap();
                    let v = json::parse(&line).unwrap();
                    assert!(v.get("ok").unwrap().as_bool().unwrap(), "{line}");
                    ids.push(
                        v.get("id").unwrap().as_str().unwrap().to_string(),
                    );
                }
                ids
            })
        })
        .collect();

    for (c, t) in clients.into_iter().enumerate() {
        let ids = t.join().unwrap();
        let want: Vec<String> =
            (0..per_client).map(|i| format!("c{c}-{i}")).collect();
        assert_eq!(ids, want, "client {c} saw out-of-order responses");
    }
    stop.store(true, Ordering::SeqCst);
    let handled = server.join().unwrap().unwrap();
    assert_eq!(handled, 4 * per_client as u64);
}

/// With a tiny queue and a slow worker, pipelined requests overflow
/// admission control; every rejection must round-trip as a parseable
/// error line with `code: "busy"` and a numeric queue depth, still in
/// per-connection submission order.
#[test]
fn backpressure_rejections_roundtrip_as_busy_lines() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let server = {
        let stop = Arc::clone(&stop);
        thread::spawn(move || {
            serve_with(
                Arc::new(StubRunner { delay_ms: 40 }),
                listener,
                opts(1, 1, 0),
                Some(stop),
            )
        })
    };

    let n = 10usize;
    let mut client = Client::connect(&addr).unwrap();
    for i in 0..n {
        client.send(&format!("r{i}"), 3).unwrap();
    }
    let mut oks = 0usize;
    let mut busys = 0usize;
    for i in 0..n {
        let line = client.read_line().unwrap();
        let v = json::parse(&line).unwrap();
        // Per-connection FIFO covers rejections too.
        assert_eq!(v.get("id").unwrap().as_str().unwrap(), format!("r{i}"));
        if v.get("ok").unwrap().as_bool().unwrap() {
            oks += 1;
        } else {
            assert_eq!(v.get("code").unwrap().as_str().unwrap(), "busy");
            // Depth is a structured field, not leaked into the text.
            let depth = v.get("queue_depth").unwrap().as_usize().unwrap();
            assert!(depth <= 1, "queue depth {depth} exceeds capacity");
            assert!(!v
                .get("error")
                .unwrap()
                .as_str()
                .unwrap()
                .contains("depth"));
            busys += 1;
        }
    }
    assert_eq!(oks + busys, n);
    assert!(oks >= 1, "no requests served");
    assert!(busys >= 1, "queue of 1 never overflowed across {n} requests");
    stop.store(true, Ordering::SeqCst);
    server.join().unwrap().unwrap();
}

/// Malformed lines get error responses without killing the connection.
#[test]
fn malformed_requests_get_error_responses() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let server = {
        let stop = Arc::clone(&stop);
        thread::spawn(move || {
            serve_with(
                Arc::new(StubRunner { delay_ms: 0 }),
                listener,
                opts(8, 2, 0),
                Some(stop),
            )
        })
    };

    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    writeln!(stream, "this is not json").unwrap();
    writeln!(stream, "{{\"id\": \"ok1\", \"seed\": 5}}").unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = json::parse(line.trim()).unwrap();
    assert!(!v.get("ok").unwrap().as_bool().unwrap());
    assert_eq!(v.get("code").unwrap().as_str().unwrap(), "bad_request");
    line.clear();
    reader.read_line(&mut line).unwrap();
    let v = json::parse(line.trim()).unwrap();
    assert!(v.get("ok").unwrap().as_bool().unwrap());
    drop(reader);
    drop(stream);
    stop.store(true, Ordering::SeqCst);
    server.join().unwrap().unwrap();
}

/// Stub runner that leases a GPU per job, then panics on a poison
/// seed *while holding the lease* — the end-to-end shape of the
/// lease-leak bug this PR guards against.
struct LeasingPanicRunner {
    fleet: FleetManager,
}

impl JobRunner for LeasingPanicRunner {
    fn run(&self, job: &Job) -> (bool, String) {
        // Non-blocking on purpose: if a previous panic leaked its
        // lease, this returns a failure line instead of hanging the
        // test forever.
        match self.fleet.try_acquire(&[0]) {
            Ok(Some(_lease)) => {
                if job.seed() == 666 {
                    panic!("poisoned job");
                }
                (
                    true,
                    format!("{{\"id\": \"{}\", \"ok\": true}}", job.id),
                )
                // _lease drops here — and during the panic unwind.
            }
            _ => (
                false,
                format!(
                    "{{\"id\": \"{}\", \"ok\": false, \
                     \"error\": \"device still leased — leak!\"}}",
                    job.id
                ),
            ),
        }
    }
}

/// Regression test: a panicking job must (a) release its GPU lease via
/// the unwind through `catch_unwind`, so the very next job can lease
/// the same device, and (b) be counted as failed in `RouterStats`.
#[test]
fn panicking_job_releases_lease_and_counts_failed() {
    let fleet = FleetManager::new(1);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let server = {
        let stop = Arc::clone(&stop);
        let fleet = fleet.clone();
        thread::spawn(move || {
            serve_with_stats(
                Arc::new(LeasingPanicRunner { fleet }),
                listener,
                opts(8, 1, 0), // one worker: a swallowed panic or a
                // leaked lease would wedge every later job
                Some(stop),
            )
        })
    };

    let mut client = Client::connect(&addr).unwrap();
    // Poison job first, then two healthy ones on the same device.
    let line = client.request("bad", 666).unwrap();
    let v = json::parse(&line).unwrap();
    assert!(!v.get("ok").unwrap().as_bool().unwrap());
    assert!(v
        .get("error")
        .unwrap()
        .as_str()
        .unwrap()
        .contains("panicked"));
    for i in 0..2 {
        let line = client.request(&format!("good{i}"), i).unwrap();
        let v = json::parse(&line).unwrap();
        assert!(
            v.get("ok").unwrap().as_bool().unwrap(),
            "job after panic failed (leaked lease?): {line}"
        );
    }
    drop(client);

    stop.store(true, Ordering::SeqCst);
    let (handled, stats) = server.join().unwrap().unwrap();
    assert_eq!(handled, 3);
    assert_eq!(stats.admitted, 3);
    assert_eq!(stats.completed, 2);
    assert_eq!(stats.failed, 1, "panic not counted as failed");
    // The fleet is whole again after shutdown.
    assert_eq!(fleet.free_devices(), vec![0]);
    assert_eq!(fleet.in_flight(), 0);
}

/// One-shot latch: `open()` releases every current and future
/// `wait()`er. Used instead of sleeps so the queue-discipline tests
/// synchronize on *events* (gate entered, N requests admitted), not on
/// wall-clock guesses.
struct Latch(std::sync::Mutex<bool>, std::sync::Condvar);

impl Latch {
    fn shared() -> Arc<Latch> {
        Arc::new(Latch(std::sync::Mutex::new(false), std::sync::Condvar::new()))
    }

    fn open(&self) {
        *self.0.lock().unwrap() = true;
        self.1.notify_all();
    }

    fn wait(&self) {
        let mut open = self.0.lock().unwrap();
        while !*open {
            open = self.1.wait(open).unwrap();
        }
    }
}

/// Stub whose "gate" job blocks until released, recording execution
/// order — deterministic scaffolding for queue-discipline tests
/// (everything behind the gate is enqueued before any of it runs).
/// `entered` opens when the gate job starts executing (the worker is
/// definitely pinned); `admitted` counts admission-validated requests
/// so tests can wait until the queue holds exactly what they sent.
struct GatedRunner {
    release: Arc<Latch>,
    entered: Arc<Latch>,
    admitted: Arc<(std::sync::Mutex<usize>, std::sync::Condvar)>,
    order: Arc<std::sync::Mutex<Vec<String>>>,
}

impl GatedRunner {
    fn new() -> GatedRunner {
        GatedRunner {
            release: Latch::shared(),
            entered: Latch::shared(),
            admitted: Arc::new((
                std::sync::Mutex::new(0),
                std::sync::Condvar::new(),
            )),
            order: Arc::new(std::sync::Mutex::new(Vec::new())),
        }
    }

    /// Block until `n` requests have passed admission (are queued or
    /// executing).
    fn wait_admitted(&self, n: usize) {
        let (lock, cv) = &*self.admitted;
        let mut count = lock.lock().unwrap();
        while *count < n {
            count = cv.wait(count).unwrap();
        }
    }
}

impl JobRunner for GatedRunner {
    fn run(&self, job: &Job) -> (bool, String) {
        if job.id == "gate" {
            self.entered.open();
            self.release.wait();
        }
        self.order.lock().unwrap().push(job.id.clone());
        (true, format!("{{\"id\": \"{}\", \"ok\": true}}", job.id))
    }

    fn admit(&self, _job: &Job) -> stadi::error::Result<()> {
        let (lock, cv) = &*self.admitted;
        *lock.lock().unwrap() += 1;
        cv.notify_all();
        Ok(())
    }
}

/// v2 requests with priorities: while the single worker is held at the
/// gate, a low→low→high pipeline reorders so the high-priority job
/// executes first — and the client still sees responses in its own
/// submission order (the per-connection reorder buffer).
#[test]
fn high_priority_requests_execute_before_queued_low_priority() {
    let runner = Arc::new(GatedRunner::new());
    let order = Arc::clone(&runner.order);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let server = {
        let stop = Arc::clone(&stop);
        let runner: Arc<dyn JobRunner> = Arc::clone(&runner);
        thread::spawn(move || {
            serve_with(runner, listener, opts(8, 1, 0), Some(stop))
        })
    };

    let mut client = Client::connect(&addr).unwrap();
    client.send("gate", 0).unwrap();
    // The (only) worker signals when it is pinned at the gate, so the
    // next four all queue behind it — no timing guesses.
    runner.entered.wait();
    let lo = GenerationSpec::new().priority(Priority::Low);
    let hi = GenerationSpec::new().priority(Priority::High);
    client.send_spec("low1", &lo).unwrap();
    client.send_spec("low2", &lo).unwrap();
    client.send_spec("high", &hi).unwrap();
    // The fence is admitted strictly after "high" was *submitted* (one
    // reader thread handles the connection's lines in order), so once
    // it passes admission the interesting three are all queued.
    client.send_spec("fence", &lo).unwrap();
    runner.wait_admitted(5);
    runner.release.open();
    // Responses come back in submission order regardless of execution
    // order (per-connection FIFO), all ok.
    for want in ["gate", "low1", "low2", "high", "fence"] {
        let line = client.read_line().unwrap();
        let v = json::parse(&line).unwrap();
        assert!(v.get("ok").unwrap().as_bool().unwrap(), "{line}");
        assert_eq!(v.get("id").unwrap().as_str().unwrap(), want);
    }
    stop.store(true, Ordering::SeqCst);
    server.join().unwrap().unwrap();
    // Execution order: the high-priority job jumped both queued lows
    // (and the same-rank fence stayed FIFO behind them).
    assert_eq!(
        *order.lock().unwrap(),
        vec!["gate", "high", "low1", "low2", "fence"],
    );
}

/// A request whose deadline passes while it queues is shed on dequeue
/// with the typed `deadline` code and structured lateness fields — and
/// counted in `RouterStats::deadline_shed`.
#[test]
fn expired_deadline_is_shed_with_typed_code() {
    let runner = Arc::new(GatedRunner::new());
    let order = Arc::clone(&runner.order);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let server = {
        let stop = Arc::clone(&stop);
        let runner: Arc<dyn JobRunner> = Arc::clone(&runner);
        thread::spawn(move || {
            serve_with_stats(runner, listener, opts(8, 1, 0), Some(stop))
        })
    };

    let mut client = Client::connect(&addr).unwrap();
    client.send("gate", 0).unwrap();
    runner.entered.wait(); // worker pinned at the gate
    // 10ms budget while the worker is held: guaranteed to expire in
    // queue. The admission latch anchors the expiry wait to the
    // moment the deadline was actually stamped, so the only wall
    // clock left is the (intrinsic) deadline budget itself, waited
    // out with a 3x margin.
    client
        .send_spec("urgent", &GenerationSpec::new().deadline_s(0.01))
        .unwrap();
    runner.wait_admitted(2);
    let stamped = std::time::Instant::now();
    while stamped.elapsed() < Duration::from_millis(30) {
        thread::sleep(Duration::from_millis(5));
    }
    runner.release.open();
    let line = client.read_line().unwrap();
    let v = json::parse(&line).unwrap();
    assert!(v.get("ok").unwrap().as_bool().unwrap(), "{line}");
    let line = client.read_line().unwrap();
    let v = json::parse(&line).unwrap();
    assert!(!v.get("ok").unwrap().as_bool().unwrap(), "{line}");
    assert_eq!(v.get("code").unwrap().as_str().unwrap(), "deadline");
    assert_eq!(v.get("deadline_s").unwrap().as_f64().unwrap(), 0.01);
    assert!(v.get("late_by_s").unwrap().as_f64().unwrap() > 0.0);
    drop(client);

    stop.store(true, Ordering::SeqCst);
    let (handled, stats) = server.join().unwrap().unwrap();
    assert_eq!(handled, 2, "shed requests still count as handled");
    assert_eq!(stats.deadline_shed, 1);
    assert_eq!(stats.failed, 1);
    assert_eq!(stats.completed, 1);
    // The shed job never reached the runner.
    assert_eq!(*order.lock().unwrap(), vec!["gate"]);
}

/// Invalid v2 specs (negative seed, bad quality) get `bad_spec` error
/// lines without killing the connection; v1 negative seeds too.
#[test]
fn invalid_specs_get_bad_spec_lines() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let server = {
        let stop = Arc::clone(&stop);
        thread::spawn(move || {
            serve_with(
                Arc::new(StubRunner { delay_ms: 0 }),
                listener,
                opts(8, 2, 0),
                Some(stop),
            )
        })
    };

    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    writeln!(stream, "{{\"id\": \"n1\", \"seed\": -5}}").unwrap();
    writeln!(
        stream,
        "{{\"id\": \"n2\", \"spec\": {{\"quality\": \"ultra\"}}}}"
    )
    .unwrap();
    writeln!(stream, "{{\"id\": \"ok\", \"seed\": 5}}").unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    for _ in 0..2 {
        line.clear();
        reader.read_line(&mut line).unwrap();
        let v = json::parse(line.trim()).unwrap();
        assert!(!v.get("ok").unwrap().as_bool().unwrap(), "{line}");
        assert_eq!(v.get("code").unwrap().as_str().unwrap(), "bad_spec");
    }
    line.clear();
    reader.read_line(&mut line).unwrap();
    let v = json::parse(line.trim()).unwrap();
    assert!(v.get("ok").unwrap().as_bool().unwrap(), "{line}");
    drop(reader);
    drop(stream);
    stop.store(true, Ordering::SeqCst);
    server.join().unwrap().unwrap();
}

// --- Real-engine path (needs artifacts + xla backend) ---------------

fn config() -> Option<EngineConfig> {
    // Backend check first (matches every other artifact-gated test
    // helper): on a bare toolchain the missing feature is the reason,
    // whether or not artifacts happen to exist.
    if !cfg!(feature = "xla-backend") {
        eprintln!("skipping: built without xla-backend");
        return None;
    }
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let mut cfg = EngineConfig::two_gpu_default(dir, &[0.0, 0.4]);
    cfg.stadi = StadiParams { m_base: 6, m_warmup: 2, ..Default::default() };
    Some(cfg)
}

#[test]
fn serves_requests_over_tcp() {
    let Some(cfg) = config() else { return };
    let core = EngineCore::new(cfg).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();

    let client_thread = thread::spawn(move || {
        let mut client = Client::connect(&addr).unwrap();
        let mut sums = Vec::new();
        for i in 0..3 {
            let line = client
                .request(&format!("r{i}"), 100 + i as u64)
                .unwrap();
            let v = json::parse(&line).unwrap();
            assert!(v.get("ok").unwrap().as_bool().unwrap(), "{line}");
            assert_eq!(
                v.get("id").unwrap().as_str().unwrap(),
                format!("r{i}")
            );
            assert!(v.get("latency_s").unwrap().as_f64().unwrap() > 0.0);
            assert!(
                v.get("sim_latency_s").unwrap().as_f64().unwrap() > 0.0
            );
            let plan = v.get("plan").unwrap();
            assert!(
                plan.get("gpu0")
                    .unwrap()
                    .get("rows")
                    .unwrap()
                    .as_usize()
                    .unwrap()
                    > 0,
                "{line}"
            );
            sums.push(v.get("latent_sum").unwrap().as_f64().unwrap());
        }
        sums
    });

    let handled = serve(core, listener, opts(8, 2, 3), None).unwrap();
    let sums = client_thread.join().unwrap();
    assert_eq!(handled, 3);
    // Distinct seeds -> distinct images. (Same-seed determinism needs a
    // pinned plan — the profiler legitimately replans between requests —
    // and is covered by core::tests::same_seed_same_plan_same_image.)
    assert!((sums[0] - sums[1]).abs() > 1e-6);
    assert!((sums[1] - sums[2]).abs() > 1e-6);
}
