"""Cross-language PRNG pinning: these exact vectors are also asserted
by rust `util::rng` tests (rust/src/util/rng.rs) — if either side
drifts, the golden-vector scheme breaks loudly here."""

import math

from compile.pcg import NormalGen, Pcg32

# Pinned outputs (generated once; both languages must match these).
U32_SEED7 = [3536637593, 1154887489, 2902756104, 1443040102]
U32_SEED42 = [1898997482, 1014631766, 4096008554, 633901381]
NORM_SEED1 = [
    2.322744198748,
    -0.446543482722,
    0.586928137232,
    0.618352916784,
]


def test_pcg32_pinned_vectors():
    r = Pcg32(7)
    assert [r.next_u32() for _ in range(4)] == U32_SEED7
    r = Pcg32(42)
    assert [r.next_u32() for _ in range(4)] == U32_SEED42


def test_normal_pinned_vectors():
    g = NormalGen(1)
    for want in NORM_SEED1:
        assert math.isclose(g.next(), want, rel_tol=0, abs_tol=1e-9)


def test_f64_in_unit_interval():
    r = Pcg32(123)
    for _ in range(1000):
        x = r.next_f64()
        assert 0.0 <= x < 1.0


def test_streams_deterministic():
    a, b = Pcg32(5), Pcg32(5)
    assert [a.next_u64() for _ in range(10)] == [b.next_u64() for _ in range(10)]
