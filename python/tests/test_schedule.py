"""Noise schedule + DDIM grid unit tests (mirrored by rust tests)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import schedule
from compile.config import SCHEDULE


def test_betas_monotone_and_bounded():
    b = schedule.betas()
    assert b.shape == (SCHEDULE.train_steps,)
    assert np.all(np.diff(b) > 0)
    assert b[0] == pytest.approx(SCHEDULE.beta_start, rel=1e-9)
    assert b[-1] == pytest.approx(SCHEDULE.beta_end, rel=1e-9)


def test_alpha_bars_decreasing_in_unit_interval():
    ab = schedule.alpha_bars()
    assert np.all(np.diff(ab) < 0)
    assert 0.0 < ab[-1] < ab[0] < 1.0


@settings(max_examples=30, deadline=None)
@given(m=st.integers(2, 500))
def test_ddim_grid_properties(m):
    g = schedule.ddim_grid(m)
    assert len(g) == m
    assert g[-1] == 0
    assert g[0] == ((m - 1) * SCHEDULE.train_steps) // m
    assert all(a > b for a, b in zip(g, g[1:]))  # strictly decreasing
    assert all(0 <= t < SCHEDULE.train_steps for t in g)


@settings(max_examples=30, deadline=None)
@given(
    m=st.sampled_from([20, 52, 100, 200]),
    warmup=st.sampled_from([0, 2, 4, 8]),
)
def test_stadi_slow_grid_alignment(m, warmup):
    """The slow grid must (a) share the warmup prefix, (b) be a subset of
    the fast grid (so sync points exist), (c) have the Eq. 4 length
    warmup + (m - warmup)/2, and (d) end at the same final timestep."""
    if (m - warmup) % 2 != 0:
        return
    fast = schedule.ddim_grid(m)
    slow = schedule.stadi_slow_grid(fast, warmup)
    assert slow[:warmup] == fast[:warmup]
    assert set(slow) <= set(fast)
    assert len(slow) == warmup + (m - warmup) // 2
    assert slow[-1] == fast[-1] == 0
    assert all(a > b for a, b in zip(slow, slow[1:]))


def test_ddim_coefficients_final_step_denoises_fully():
    # t_to = -1: alpha_bar_s = 1 => x0_hat = (x - sigma_t*eps)/alpha_t.
    ab = schedule.alpha_bars()
    t = 100
    cx, ce = schedule.ddim_coefficients(t, -1)
    assert cx == pytest.approx(1.0 / np.sqrt(ab[t]), rel=1e-9)
    assert ce == pytest.approx(-np.sqrt(1 - ab[t]) / np.sqrt(ab[t]), rel=1e-9)


def test_ddim_coefficients_noop_for_same_t():
    cx, ce = schedule.ddim_coefficients(500, 500)
    assert cx == pytest.approx(1.0)
    assert ce == pytest.approx(0.0, abs=1e-12)


def test_grid_coefficients_cover_grid():
    g = schedule.ddim_grid(10)
    pairs = schedule.grid_coefficients(g)
    assert len(pairs) == 10
    # Composing all coef_x factors telescopes to 1/alpha_{t0} =
    # 1/sqrt(alpha_bar at first grid point).
    ab = schedule.alpha_bars()
    prod = np.prod([p[0] for p in pairs])
    assert prod == pytest.approx(1.0 / np.sqrt(ab[g[0]]), rel=1e-6)
