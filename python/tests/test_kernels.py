"""L1: every Pallas kernel vs its pure-jnp oracle (ref.py).

hypothesis sweeps shapes/seeds; assert_allclose at f32 tolerance.
This is the core correctness signal for the kernels that end up inside
the AOT'd HLO.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import attention as attn_k
from compile.kernels import ddim as ddim_k
from compile.kernels import layernorm as ln_k
from compile.kernels import mlp as mlp_k
from compile.kernels import ref

SETTINGS = dict(max_examples=20, deadline=None)


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


# ---------------------------------------------------------------- attention

@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    heads=st.sampled_from([1, 2, 4]),
    tq=st.sampled_from([16, 64, 128]),
    tk=st.sampled_from([64, 256]),
    dh=st.sampled_from([8, 24, 32]),
)
def test_attention_matches_ref(seed, heads, tq, tk, dh):
    rng = np.random.default_rng(seed)
    q, k, v = (rand(rng, heads, t, dh) for t in (tq, tk, tk))
    got = attn_k.attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
    want = ref.attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_attention_softmax_rows_sum_to_one_property():
    # With v = identity columns, attention output rows are the softmax
    # probabilities; they must sum to 1.
    rng = np.random.default_rng(0)
    q = rand(rng, 2, 16, 8)
    k = rand(rng, 2, 16, 8)
    v = np.tile(np.eye(16, 8, dtype=np.float32), (2, 1, 1))
    out = np.asarray(
        attn_k.attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
    )
    # rows of softmax over first 8 keys sum to <= 1 (proper distribution
    # when keys >= dim); compare against the oracle instead for exactness
    want = np.asarray(
        ref.attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
    )
    assert_allclose(out, want, rtol=1e-5, atol=1e-6)


def test_attention_invariant_to_key_shift():
    # Softmax is invariant to adding a constant to all scores; shifting
    # every key by the same vector along q's direction is not, but adding
    # a constant to the *scores* via scaling q to zero makes output the
    # mean of v. q=0 => uniform attention => output == mean(v).
    rng = np.random.default_rng(1)
    k = rand(rng, 1, 32, 8)
    v = rand(rng, 1, 32, 8)
    q = np.zeros((1, 4, 8), np.float32)
    out = np.asarray(
        attn_k.attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
    )
    want = np.tile(v.mean(axis=1, keepdims=True), (1, 4, 1))
    assert_allclose(out, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- layernorm

@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    t=st.sampled_from([32, 64, 96, 256]),
    d=st.sampled_from([16, 96]),
)
def test_layernorm_matches_ref(seed, t, d):
    rng = np.random.default_rng(seed)
    x, scale, shift = rand(rng, t, d), rand(rng, d), rand(rng, d)
    got = ln_k.layernorm_modulate(
        jnp.asarray(x), jnp.asarray(scale), jnp.asarray(shift)
    )
    want = ref.layernorm_modulate(
        jnp.asarray(x), jnp.asarray(scale), jnp.asarray(shift)
    )
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_layernorm_output_is_normalized():
    rng = np.random.default_rng(2)
    x = rand(rng, 64, 96) * 10 + 5
    out = np.asarray(
        ln_k.layernorm_modulate(
            jnp.asarray(x),
            jnp.zeros(96, np.float32),
            jnp.zeros(96, np.float32),
        )
    )
    assert_allclose(out.mean(axis=-1), np.zeros(64), atol=1e-4)
    assert_allclose(out.std(axis=-1), np.ones(64), atol=1e-3)


# ---------------------------------------------------------------- mlp

@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    t=st.sampled_from([32, 96, 256]),
    d=st.sampled_from([16, 96]),
    ratio=st.sampled_from([2, 4]),
)
def test_mlp_matches_ref(seed, t, d, ratio):
    rng = np.random.default_rng(seed)
    f = ratio * d
    # Realistic weight scale (the model initializes at std 0.02); unit-
    # scale weights would blow activations to O(100) where f32
    # accumulation-order differences dominate.
    x = rand(rng, t, d)
    w1 = rand(rng, d, f) / np.sqrt(d).astype(np.float32)
    b1 = rand(rng, f)
    w2 = rand(rng, f, d) / np.sqrt(f).astype(np.float32)
    b2 = rand(rng, d)
    args = [jnp.asarray(a) for a in (x, w1, b1, w2, b2)]
    assert_allclose(
        np.asarray(mlp_k.mlp(*args)),
        np.asarray(ref.mlp(*args)),
        rtol=1e-4, atol=1e-4,
    )


def test_gelu_fixed_points():
    # GELU(0) = 0; GELU(x) ~ x for large x; GELU(-x) ~ 0 for large x.
    x = jnp.asarray(np.array([0.0, 10.0, -10.0], np.float32))
    y = np.asarray(ref.gelu(x))
    assert_allclose(y[0], 0.0, atol=1e-7)
    assert_allclose(y[1], 10.0, rtol=1e-5)
    assert_allclose(y[2], 0.0, atol=1e-4)


# ---------------------------------------------------------------- ddim

@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    cx=st.floats(0.1, 2.0),
    ce=st.floats(-1.0, 1.0),
)
def test_ddim_update_matches_ref(seed, cx, ce):
    rng = np.random.default_rng(seed)
    x = rand(rng, 32, 32, 4)
    eps = rand(rng, 32, 32, 4)
    got = ddim_k.ddim_update(jnp.asarray(x), jnp.asarray(eps), cx, ce)
    want = ref.ddim_update(
        jnp.asarray(x), jnp.asarray(eps),
        jnp.float32(cx), jnp.float32(ce),
    )
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_ddim_identity_step():
    # coef_x = 1, coef_eps = 0 must be the identity.
    rng = np.random.default_rng(3)
    x = rand(rng, 32, 32, 4)
    eps = rand(rng, 32, 32, 4)
    out = np.asarray(
        ddim_k.ddim_update(jnp.asarray(x), jnp.asarray(eps), 1.0, 0.0)
    )
    assert_allclose(out, x, atol=0)
