"""L2 model tests: pallas/ref path agreement, patch-composition
exactness, staleness semantics, parameter packing."""

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model as M
from compile.config import MODEL

CFG = MODEL


@pytest.fixture(scope="module")
def params():
    return jnp.asarray(M.init_params_flat(CFG))


def _rand_inputs(seed, h):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((h, CFG.latent_w, CFG.latent_c)).astype(np.float32)
    kv = rng.standard_normal(
        (CFG.layers, CFG.tokens_full, 2 * CFG.dim)
    ).astype(np.float32)
    cond = rng.standard_normal((CFG.dim,)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(kv), jnp.asarray(cond)


def test_param_spec_matches_flat_len(params):
    assert params.shape == (M.param_count(CFG),)


def test_param_unpack_roundtrip(params):
    p = M.unpack_params(params, CFG)
    total = sum(int(np.prod(v.shape)) for v in p.values())
    assert total == M.param_count(CFG)
    # First spec entry starts at offset 0.
    name0, shape0 = M.param_spec(CFG)[0]
    n0 = int(np.prod(shape0))
    assert_allclose(
        np.asarray(p[name0]).reshape(-1), np.asarray(params[:n0])
    )


def test_patchify_unpatchify_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(
        rng.standard_normal((16, CFG.latent_w, CFG.latent_c)).astype(np.float32)
    )
    tok = M.patchify(x, CFG)
    assert tok.shape == (CFG.tokens_for_rows(16), CFG.patch ** 2 * CFG.latent_c)
    back = M.unpatchify(tok, 16, CFG)
    assert_allclose(np.asarray(back), np.asarray(x), atol=0)


@pytest.mark.parametrize("h,row_off", [(8, 0), (8, 24), (16, 8), (4, 28)])
def test_pallas_matches_ref_path(params, h, row_off):
    x, kv, cond = _rand_inputs(5, h)
    e1, k1 = M.denoiser_patch(params, x, kv, row_off, 321.0, cond,
                              CFG, use_pallas=False)
    e2, k2 = M.denoiser_patch(params, x, kv, row_off, 321.0, cond,
                              CFG, use_pallas=True)
    assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-4, atol=1e-5)
    assert_allclose(np.asarray(k1), np.asarray(k2), rtol=1e-4, atol=1e-5)


def test_patches_with_fresh_buffers_compose_to_full(params):
    """Patch parallelism exactness property: when every device gets
    *fresh* peer KV (no staleness), splitting the image into patches
    must reproduce the full-image forward bit-close. This is the
    correctness foundation the paper's warmup phase relies on."""
    rng = np.random.default_rng(9)
    x_full = jnp.asarray(
        rng.standard_normal(
            (CFG.latent_h, CFG.latent_w, CFG.latent_c)
        ).astype(np.float32)
    )
    cond = jnp.asarray(rng.standard_normal((CFG.dim,)).astype(np.float32))
    t = 700.0

    eps_full, kv_full = M.fresh_kv_for_full(params, x_full, t, cond, CFG)

    # Device 0 gets rows [0, 12), device 1 rows [12, 32); both attend
    # over the *fresh* kv_full buffer (own slice is recomputed inside,
    # which must equal the full-forward slice).
    splits = [(0, 12), (12, 20)]
    outs = []
    for row0, h in splits:
        xp = x_full[row0 : row0 + h]
        eps_p, kv_p = M.denoiser_patch(
            params, xp, kv_full, row0, t, cond, CFG, use_pallas=False
        )
        outs.append((row0, h, eps_p, kv_p))

    recomposed = np.concatenate([np.asarray(o[2]) for o in outs], axis=0)
    assert_allclose(recomposed, np.asarray(eps_full), rtol=1e-4, atol=1e-5)

    # The fresh KV each patch returns equals the full forward's slice.
    for row0, h, _, kv_p in outs:
        t0 = CFG.tokens_for_rows(row0)
        t1 = t0 + CFG.tokens_for_rows(h)
        assert_allclose(
            np.asarray(kv_p),
            np.asarray(kv_full[:, t0:t1]),
            rtol=1e-4, atol=1e-5,
        )


def test_stale_buffer_changes_output(params):
    """Sanity: attention really reads the peer region of the KV buffer
    (if it didn't, patch parallelism would be trivially exact and the
    paper's buffer exchange pointless)."""
    x, kv, cond = _rand_inputs(6, 8)
    e1, _ = M.denoiser_patch(params, x, kv, 0, 100.0, cond, CFG, False)
    kv2 = kv.at[:, CFG.tokens_full // 2 :].add(1.0)  # perturb peer region
    e2, _ = M.denoiser_patch(params, x, kv2, 0, 100.0, cond, CFG, False)
    assert float(jnp.abs(e1 - e2).max()) > 1e-4


def test_own_region_of_stale_buffer_is_ignored(params):
    """The device's own slice of kv_stale is overwritten with fresh KV
    before attention, so perturbing it must NOT change the output."""
    x, kv, cond = _rand_inputs(8, 8)
    row_off = 16
    t0 = CFG.tokens_for_rows(row_off)
    t1 = t0 + CFG.tokens_for_rows(8)
    e1, _ = M.denoiser_patch(params, x, kv, row_off, 100.0, cond, CFG, False)
    kv2 = kv.at[:, t0:t1].add(123.0)
    e2, _ = M.denoiser_patch(params, x, kv2, row_off, 100.0, cond, CFG, False)
    assert_allclose(np.asarray(e1), np.asarray(e2), atol=0)


def test_timestep_and_cond_affect_output(params):
    x, kv, cond = _rand_inputs(10, 8)
    e1, _ = M.denoiser_patch(params, x, kv, 0, 100.0, cond, CFG, False)
    e2, _ = M.denoiser_patch(params, x, kv, 0, 900.0, cond, CFG, False)
    e3, _ = M.denoiser_patch(params, x, kv, 0, 100.0, cond + 1.0, CFG, False)
    assert float(jnp.abs(e1 - e2).max()) > 1e-4
    assert float(jnp.abs(e1 - e3).max()) > 1e-4


def test_timestep_embedding_range():
    emb = M.timestep_embedding(jnp.float32(500.0), 64)
    e = np.asarray(emb)
    assert e.shape == (64,)
    assert np.all(np.abs(e) <= 1.0 + 1e-6)  # cos/sin bounded
