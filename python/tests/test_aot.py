"""AOT manifest / artifact consistency tests.

These run against the artifacts/ directory if it exists (skip
otherwise so `pytest` works pre-`make artifacts`).
"""

import json
import os

import numpy as np
import pytest

from compile import model as M
from compile.config import MODEL

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_model_matches_config(manifest):
    m = manifest["model"]
    assert m["latent_h"] == MODEL.latent_h
    assert m["dim"] == MODEL.dim
    assert m["layers"] == MODEL.layers
    assert m["param_count"] == M.param_count(MODEL)
    assert m["tokens_full"] == MODEL.tokens_full


def test_params_bin_matches_seeded_init(manifest):
    flat = np.fromfile(os.path.join(ART, "params.bin"), dtype=np.float32)
    assert flat.shape == (manifest["model"]["param_count"],)
    ref = M.init_params_flat(MODEL, manifest["model"]["params_seed"])
    np.testing.assert_allclose(flat, ref, atol=0)


def test_all_patch_heights_present(manifest):
    for h in MODEL.patch_heights:
        key = f"denoiser_h{h}"
        assert key in manifest["artifacts"], key
        art = manifest["artifacts"][key]
        path = os.path.join(ART, art["file"])
        assert os.path.getsize(path) == art["bytes"]
        # input signature sanity
        shapes = {i["name"]: i["shape"] for i in art["inputs"]}
        assert shapes["x_patch"] == [h, MODEL.latent_w, MODEL.latent_c]
        assert shapes["kv_stale"] == [
            MODEL.layers, MODEL.tokens_full, 2 * MODEL.dim,
        ]


def test_param_spec_recorded_in_order(manifest):
    spec = [(e["name"], tuple(e["shape"])) for e in manifest["param_spec"]]
    assert spec == [(n, tuple(s)) for n, s in M.param_spec(MODEL)]


def test_golden_files_exist(manifest):
    for name in ("schedule.json", "denoiser.json", "trajectory.json",
                 "features.json"):
        p = os.path.join(ART, "golden", name)
        assert os.path.exists(p), name
        with open(p) as f:
            json.load(f)  # valid json


def test_golden_denoiser_reproducible(manifest):
    """Recompute the golden denoiser output from the recorded seed and
    compare — guards against silent weight or model drift."""
    import jax.numpy as jnp

    from compile import pcg

    with open(os.path.join(ART, "golden", "denoiser.json")) as f:
        g = json.load(f)
    gen = pcg.NormalGen(g["seed"])
    h = g["h"]
    x = gen.vec_f32(h * MODEL.latent_w * MODEL.latent_c).reshape(
        h, MODEL.latent_w, MODEL.latent_c
    )
    kv = gen.vec_f32(MODEL.layers * MODEL.tokens_full * 2 * MODEL.dim).reshape(
        MODEL.layers, MODEL.tokens_full, 2 * MODEL.dim
    )
    cond = gen.vec_f32(MODEL.dim)
    flat = np.fromfile(os.path.join(ART, "params.bin"), dtype=np.float32)
    eps, _ = M.denoiser_patch(
        jnp.asarray(flat), jnp.asarray(x), jnp.asarray(kv),
        g["row_off"], g["t"], jnp.asarray(cond), MODEL, use_pallas=True,
    )
    eps = np.asarray(eps)
    np.testing.assert_allclose(
        eps.reshape(-1)[:16], np.array(g["eps_first16"]), rtol=1e-5
    )
    np.testing.assert_allclose(eps.sum(), g["eps_sum"], rtol=1e-4)
