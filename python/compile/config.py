"""Shared model / schedule configuration for the STADI reproduction.

Single source of truth for every dimension that crosses the
python (build-time) <-> rust (run-time) boundary. `aot.py` serializes
this into `artifacts/manifest.json`; the rust `runtime::artifacts`
module re-reads it so the two sides can never disagree silently.

The model is a miniature DiT-style denoiser standing in for SDXL
(see DESIGN.md §3 for the substitution argument): what matters for the
paper's scheduler is that (a) compute scales with patch rows, and
(b) attention layers need the *full* (possibly stale) KV buffer, which
is exactly the activation DistriFusion/STADI exchange between GPUs.
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    # Latent geometry ("1024x1024 image" <-> 32x32x4 latent, paper §V:
    # P_total = 32 spatial rows).
    latent_h: int = 32
    latent_w: int = 32
    latent_c: int = 4
    # DiT patchify size (2x2 latent pixels per token).
    patch: int = 2
    # Transformer width / depth.
    dim: int = 96
    heads: int = 4
    layers: int = 3
    mlp_ratio: int = 4
    # Sinusoidal timestep embedding width (pre-MLP).
    temb_dim: int = 64
    # Patch-height granularity for AOT variants. Spatial adaptation may
    # only pick row counts that are multiples of this (paper §III-D:
    # "P_total must also satisfy hardware/operator constraints").
    # 2 latent rows = 1 token row, the finest the 2x2 patchify allows;
    # coarser granularity measurably blunts SA at mild imbalance
    # (EXPERIMENTS.md Fig. 8 notes).
    row_granularity: int = 2

    @property
    def head_dim(self) -> int:
        return self.dim // self.heads

    @property
    def tokens_per_row_block(self) -> int:
        """Tokens contributed by `patch` latent rows (one token row)."""
        return self.latent_w // self.patch

    @property
    def token_rows(self) -> int:
        return self.latent_h // self.patch

    @property
    def tokens_full(self) -> int:
        return self.token_rows * self.tokens_per_row_block

    def tokens_for_rows(self, rows: int) -> int:
        assert rows % self.patch == 0, rows
        return (rows // self.patch) * self.tokens_per_row_block

    @property
    def patch_heights(self) -> tuple:
        """All AOT'd patch heights (latent rows)."""
        g = self.row_granularity
        return tuple(range(g, self.latent_h + 1, g))


@dataclass(frozen=True)
class ScheduleConfig:
    """SD-style scaled-linear beta schedule (matches rust model/schedule.rs)."""

    train_steps: int = 1000
    beta_start: float = 0.00085
    beta_end: float = 0.012


@dataclass(frozen=True)
class FeatureNetConfig:
    """Fixed random conv net used for LPIPS/FID proxy metrics (DESIGN.md §3)."""

    channels: tuple = (16, 32, 64)
    kernel: int = 3
    seed: int = 1234


MODEL = ModelConfig()
SCHEDULE = ScheduleConfig()
FEATURES = FeatureNetConfig()

# Seed for the denoiser weights baked into artifacts/params.bin.
PARAMS_SEED = 42
