"""L2: patch-parallel mini-DiT denoiser eps_theta(x_patch, t, cond).

Stands in for SDXL (DESIGN.md §3). The forward pass is written exactly
the way DistriFusion/STADI need it for patch parallelism:

  * each device only computes tokens for its own latent rows
    (compute scales with patch height h), and
  * every attention layer reads K/V for the *full* image from a
    stale buffer input, with the device's own token slice replaced by
    the freshly-computed K/V (jax.lax.dynamic_update_slice at a
    *runtime* token offset, so one AOT artifact per patch height works
    for any placement), and
  * the fresh own-token K/V of every layer is returned so the rust
    coordinator can scatter it into its full buffer and ship it to
    peers (the paper's "update buffer asynchronously").

Weights are NOT baked into the HLO: they are a single flat f32 input
(artifacts/params.bin) unpacked by static slicing, so all patch-height
variants share one parameter file and artifacts stay small.

`use_pallas=True` routes LN / attention / MLP through the L1 Pallas
kernels; `False` uses the pure-jnp oracles — pytest asserts both paths
agree, and AOT lowers the Pallas path.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np

from .config import MODEL, PARAMS_SEED
from .kernels import attention as attn_k
from .kernels import layernorm as ln_k
from .kernels import mlp as mlp_k
from .kernels import ref


# --------------------------------------------------------------------------
# Parameter spec: explicit (name, shape) list; the flat packing order is
# part of the artifact ABI and is recorded in manifest.json.
# --------------------------------------------------------------------------

def param_spec(cfg=MODEL):
    d = cfg.dim
    f = cfg.mlp_ratio * d
    pp = cfg.patch * cfg.patch * cfg.latent_c  # pixels per token
    spec = [
        ("embed_w", (pp, d)),
        ("embed_b", (d,)),
        ("pos_emb", (cfg.tokens_full, d)),
        ("temb_w1", (cfg.temb_dim, d)),
        ("temb_b1", (d,)),
        ("temb_w2", (d, d)),
        ("temb_b2", (d,)),
    ]
    for i in range(cfg.layers):
        spec += [
            (f"blk{i}_mod_w", (d, 6 * d)),
            (f"blk{i}_mod_b", (6 * d,)),
            (f"blk{i}_qkv_w", (d, 3 * d)),
            (f"blk{i}_qkv_b", (3 * d,)),
            (f"blk{i}_o_w", (d, d)),
            (f"blk{i}_o_b", (d,)),
            (f"blk{i}_mlp_w1", (d, f)),
            (f"blk{i}_mlp_b1", (f,)),
            (f"blk{i}_mlp_w2", (f, d)),
            (f"blk{i}_mlp_b2", (d,)),
        ]
    spec += [
        ("final_mod_w", (d, 2 * d)),
        ("final_mod_b", (2 * d,)),
        ("final_w", (d, pp)),
        ("final_b", (pp,)),
    ]
    return spec


def param_count(cfg=MODEL):
    return sum(int(np.prod(s)) for _, s in param_spec(cfg))


def init_params_flat(cfg=MODEL, seed=PARAMS_SEED):
    """Seeded flat f32 parameter vector (written to params.bin).

    Weight matrices use fan-in (Xavier-ish) scaling so activations and
    residual contributions are O(1) — a *trained* denoiser's effective
    sensitivity. Tiny-init weights (e.g. std 0.02 everywhere) would
    mute cross-patch attention so much that stale peer buffers cost
    nothing and Table II's quality comparison degenerates (PSNR w/Orig
    ≈ 75 dB instead of the paper's ≈ 24 dB band).
    """
    rng = np.random.default_rng(seed)
    parts = []
    for name, shape in param_spec(cfg):
        if name.endswith("_b"):
            parts.append(np.zeros(shape, np.float32))
        elif name == "pos_emb":
            parts.append(
                rng.normal(0.0, 0.02, size=shape).astype(np.float32)
            )
        else:
            fan_in = shape[0] if len(shape) == 2 else shape[-1]
            std = (1.0 / fan_in) ** 0.5
            parts.append(
                rng.normal(0.0, std, size=shape).astype(np.float32)
            )
    return np.concatenate([p.reshape(-1) for p in parts])


def unpack_params(flat, cfg=MODEL):
    """Flat vector -> dict of named arrays via static slices."""
    out = {}
    off = 0
    for name, shape in param_spec(cfg):
        n = int(np.prod(shape))
        out[name] = jax.lax.slice(flat, (off,), (off + n,)).reshape(shape)
        off += n
    return out


# --------------------------------------------------------------------------
# Forward pass pieces
# --------------------------------------------------------------------------

TRAIN_STEPS_F = 1000.0

def timestep_embedding(t, dim):
    """Band-limited sinusoidal embedding of a scalar timestep, [dim].

    Frequencies are log-spaced between 0.5 and 8 cycles over the full
    [0, train_steps] range (min period = 125 t-units). Rationale: with
    *random* weights, the classic max-frequency-1 embedding makes
    eps_theta oscillate arbitrarily fast in t, violating the
    smoothness-in-t premise behind DPM-Solver/DDIM convergence (and
    paper Thm. 2) that *trained* denoisers satisfy; band-limiting
    restores the property the substitution must preserve (DESIGN.md
    §3). Grid spacings up to ~60 t-units then sit comfortably inside
    the first-order regime.
    """
    half = dim // 2
    lo, hi = 0.5, 8.0
    freqs = (
        2.0
        * math.pi
        * lo
        * jnp.exp(
            math.log(hi / lo)
            * jnp.arange(half, dtype=jnp.float32)
            / half
        )
    )
    ang = (t / TRAIN_STEPS_F) * freqs
    return jnp.concatenate([jnp.cos(ang), jnp.sin(ang)])


def patchify(x_patch, cfg=MODEL):
    """[h, W, C] latent rows -> [T_own, patch*patch*C] tokens."""
    h, w, c = x_patch.shape
    p = cfg.patch
    x = x_patch.reshape(h // p, p, w // p, p, c)
    x = jnp.transpose(x, (0, 2, 1, 3, 4))  # [h/p, w/p, p, p, c]
    return x.reshape((h // p) * (w // p), p * p * c)


def unpatchify(tokens, h, cfg=MODEL):
    """[T_own, patch*patch*C] -> [h, W, C]."""
    p = cfg.patch
    w = cfg.latent_w
    x = tokens.reshape(h // p, w // p, p, p, cfg.latent_c)
    x = jnp.transpose(x, (0, 2, 1, 3, 4))
    return x.reshape(h, w, cfg.latent_c)


def _ln_mod(x, scale, shift, use_pallas):
    if use_pallas:
        return ln_k.layernorm_modulate(x, scale, shift)
    return ref.layernorm_modulate(x, scale, shift)


def _attn(q, k, v, use_pallas):
    if use_pallas:
        return attn_k.attention(q, k, v)
    return ref.attention(q, k, v)


def _mlp(x, w1, b1, w2, b2, use_pallas):
    if use_pallas:
        return mlp_k.mlp(x, w1, b1, w2, b2)
    return ref.mlp(x, w1, b1, w2, b2)


def _split_heads(x, cfg):
    """[T, D] -> [H, T, dh]"""
    t = x.shape[0]
    return jnp.transpose(
        x.reshape(t, cfg.heads, cfg.head_dim), (1, 0, 2)
    )


def _merge_heads(x):
    """[H, T, dh] -> [T, D]"""
    h, t, dh = x.shape
    return jnp.transpose(x, (1, 0, 2)).reshape(t, h * dh)


def denoiser_patch(params_flat, x_patch, kv_stale, row_off, t, cond,
                   cfg=MODEL, use_pallas=True):
    """One denoiser forward over a device's patch.

    Args:
      params_flat: [param_count] f32 — weights (artifacts/params.bin).
      x_patch:     [h, W, C] — this device's latent rows (fresh).
      kv_stale:    [L, T_full, 2D] — per-layer full-image K/V buffers,
                   fresh for this device's own slice of the *previous*
                   step, stale (peer-supplied) elsewhere.
      row_off:     scalar i32 — first latent row of the patch.
      t:           scalar f32 — diffusion timestep index.
      cond:        [D] — conditioning vector (prompt-embedding stand-in).

    Returns:
      (eps_patch [h, W, C], kv_fresh [L, T_own, 2D])
    """
    p = unpack_params(params_flat, cfg)
    h = x_patch.shape[0]
    t_own = cfg.tokens_for_rows(h)
    tok_off = (row_off // cfg.patch) * cfg.tokens_per_row_block

    tok = patchify(x_patch, cfg) @ p["embed_w"] + p["embed_b"]
    pos = jax.lax.dynamic_slice(
        p["pos_emb"], (tok_off, 0), (t_own, cfg.dim)
    )
    tok = tok + pos

    temb = timestep_embedding(t, cfg.temb_dim)
    c = ref.gelu(temb @ p["temb_w1"] + p["temb_b1"])
    c = c @ p["temb_w2"] + p["temb_b2"]
    c = c + cond

    kv_fresh = []
    for i in range(cfg.layers):
        mod = c @ p[f"blk{i}_mod_w"] + p[f"blk{i}_mod_b"]
        s1, sh1, g1, s2, sh2, g2 = jnp.split(mod, 6)

        xn = _ln_mod(tok, s1, sh1, use_pallas)
        qkv = xn @ p[f"blk{i}_qkv_w"] + p[f"blk{i}_qkv_b"]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        kv_own = jnp.concatenate([k, v], axis=-1)  # [T_own, 2D]
        kv_fresh.append(kv_own)

        kv_full = jax.lax.dynamic_update_slice(
            kv_stale[i], kv_own, (tok_off, 0)
        )
        k_full, v_full = kv_full[:, : cfg.dim], kv_full[:, cfg.dim :]

        o = _attn(
            _split_heads(q, cfg),
            _split_heads(k_full, cfg),
            _split_heads(v_full, cfg),
            use_pallas,
        )
        # Residual gates at 1 + g: with random weights the raw adaLN
        # gates are ~N(0, 0.02), which would dampen cross-patch
        # attention to noise level and make patch parallelism trivially
        # exact (stale peer KV would cost nothing). Trained diffusion
        # models have O(1) effective residual coupling — the property
        # the substitution must preserve for Table II to be meaningful.
        tok = tok + (1.0 + g1) * (
            _merge_heads(o) @ p[f"blk{i}_o_w"] + p[f"blk{i}_o_b"]
        )

        xn2 = _ln_mod(tok, s2, sh2, use_pallas)
        tok = tok + (1.0 + g2) * _mlp(
            xn2,
            p[f"blk{i}_mlp_w1"],
            p[f"blk{i}_mlp_b1"],
            p[f"blk{i}_mlp_w2"],
            p[f"blk{i}_mlp_b2"],
            use_pallas,
        )

    fmod = c @ p["final_mod_w"] + p["final_mod_b"]
    sf, shf = jnp.split(fmod, 2)
    xn = _ln_mod(tok, sf, shf, use_pallas)
    out = xn @ p["final_w"] + p["final_b"]
    return unpatchify(out, h, cfg), jnp.stack(kv_fresh)


def fresh_kv_for_full(params_flat, x_full, t, cond, cfg=MODEL,
                      use_pallas=False):
    """Fully-fresh KV buffers for a full-image forward (no staleness).

    Convenience for tests and for initializing warmup: run the full
    image as one patch with a zero stale buffer; the returned kv_fresh
    covers all tokens.
    """
    kv0 = jnp.zeros((cfg.layers, cfg.tokens_full, 2 * cfg.dim), jnp.float32)
    eps, kv = denoiser_patch(
        params_flat, x_full, kv0, 0, t, cond, cfg, use_pallas
    )
    return eps, kv
