"""PCG32 + Box-Muller normals, bit-identical to rust `util::rng`.

The cross-layer golden vectors (artifacts/golden/*.json) need inputs
that BOTH sides can regenerate exactly. numpy's Philox/PCG streams are
not practical to mirror in no-dependency rust, so the repo pins this
tiny PCG32 implementation on both sides; `python/tests/test_pcg.py` and
rust `util::rng` tests both check the same hardcoded vectors.
"""

import math

M64 = (1 << 64) - 1
MULT = 6364136223846793005
DEFAULT_STREAM = 0xDA3E39CB94B95BDB


class Pcg32:
    def __init__(self, seed: int, stream: int = DEFAULT_STREAM):
        self.inc = ((stream << 1) | 1) & M64
        self.state = 0
        self.next_u32()
        self.state = (self.state + seed) & M64
        self.next_u32()

    def next_u32(self) -> int:
        old = self.state
        self.state = (old * MULT + self.inc) & M64
        xorshifted = (((old >> 18) ^ old) >> 27) & 0xFFFFFFFF
        rot = old >> 59
        return ((xorshifted >> rot) | (xorshifted << ((-rot) & 31))) & 0xFFFFFFFF

    def next_u64(self) -> int:
        return (self.next_u32() << 32) | self.next_u32()

    def next_f64(self) -> float:
        return (self.next_u64() >> 11) / float(1 << 53)

    def next_f32(self) -> float:
        # f32 rounding applied by the caller when needed.
        return (self.next_u32() >> 8) / float(1 << 24)


class NormalGen:
    """Box-Muller over Pcg32, mirroring rust NormalGen exactly."""

    def __init__(self, seed: int):
        self.rng = Pcg32(seed)
        self.spare = None

    def next(self) -> float:
        if self.spare is not None:
            s, self.spare = self.spare, None
            return s
        u1 = 1.0 - self.rng.next_f64()
        u2 = self.rng.next_f64()
        r = math.sqrt(-2.0 * math.log(u1))
        th = 2.0 * math.pi * u2
        self.spare = r * math.sin(th)
        return r * math.cos(th)

    def vec_f32(self, n: int):
        import numpy as np

        return np.array([self.next() for _ in range(n)], dtype=np.float32)
