"""AOT driver: lower the L2 model (with L1 Pallas kernels) to HLO text.

Run once at build time (`make artifacts`); rust loads the outputs via
PJRT and python never appears on the request path again.

Interchange format is HLO *text*, not `.serialize()`: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (to --out-dir, default ../artifacts):
  denoiser_h{h}.hlo.txt   one per patch height h in MODEL.patch_heights
  ddim_update.hlo.txt     full-latent DDIM step (Pallas kernel)
  features.hlo.txt        random-feature extractor for LPIPS/FID proxy
  params.bin              flat f32 denoiser weights (seeded)
  manifest.json           the ABI: shapes, packing order, schedule params
  golden/*.json           cross-layer golden vectors for cargo tests
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import features, model, pcg, schedule
from .config import MODEL, PARAMS_SEED, SCHEDULE
from .kernels import ddim as ddim_k


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True; the
    rust side unwraps with to_tuple{1,2}())."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default elides big literals as
    # "{...}", which the rust-side HLO parser silently reads as zeros —
    # the feature net's conv weights are baked as constants and must
    # survive the text round-trip.
    text = comp.as_hlo_text(True)
    assert "{...}" not in text, "elided constants would round-trip as zeros"
    return text


def _write(path: str, text: str) -> dict:
    with open(path, "w") as f:
        f.write(text)
    digest = hashlib.sha256(text.encode()).hexdigest()[:16]
    print(f"  wrote {path} ({len(text)} bytes, sha256:{digest})")
    return {"bytes": len(text), "sha256_16": digest}


def lower_denoiser(h: int):
    cfg = MODEL
    t_own = cfg.tokens_for_rows(h)
    sig = dict(
        inputs=[
            {"name": "params", "shape": [model.param_count(cfg)], "dtype": "f32"},
            {"name": "x_patch", "shape": [h, cfg.latent_w, cfg.latent_c], "dtype": "f32"},
            {"name": "kv_stale", "shape": [cfg.layers, cfg.tokens_full, 2 * cfg.dim], "dtype": "f32"},
            {"name": "row_off", "shape": [], "dtype": "i32"},
            {"name": "t", "shape": [], "dtype": "f32"},
            {"name": "cond", "shape": [cfg.dim], "dtype": "f32"},
        ],
        outputs=[
            {"name": "eps_patch", "shape": [h, cfg.latent_w, cfg.latent_c], "dtype": "f32"},
            {"name": "kv_fresh", "shape": [cfg.layers, t_own, 2 * cfg.dim], "dtype": "f32"},
        ],
    )
    shapes = [
        jax.ShapeDtypeStruct(tuple(i["shape"]), jnp.float32 if i["dtype"] == "f32" else jnp.int32)
        for i in sig["inputs"]
    ]
    fn = lambda p, x, kv, ro, t, c: model.denoiser_patch(  # noqa: E731
        p, x, kv, ro, t, c, MODEL, use_pallas=True
    )
    lowered = jax.jit(fn).lower(*shapes)
    return to_hlo_text(lowered), sig


def lower_ddim():
    cfg = MODEL
    shp = (cfg.latent_h, cfg.latent_w, cfg.latent_c)
    sig = dict(
        inputs=[
            {"name": "x", "shape": list(shp), "dtype": "f32"},
            {"name": "eps", "shape": list(shp), "dtype": "f32"},
            {"name": "coef_x", "shape": [], "dtype": "f32"},
            {"name": "coef_eps", "shape": [], "dtype": "f32"},
        ],
        outputs=[{"name": "x_next", "shape": list(shp), "dtype": "f32"}],
    )
    fn = lambda x, e, cx, ce: (ddim_k.ddim_update(x, e, cx, ce),)  # noqa: E731
    xs = jax.ShapeDtypeStruct(shp, jnp.float32)
    sc = jax.ShapeDtypeStruct((), jnp.float32)
    lowered = jax.jit(fn).lower(xs, xs, sc, sc)
    return to_hlo_text(lowered), sig


def lower_features():
    cfg = MODEL
    shp = (cfg.latent_h, cfg.latent_w, cfg.latent_c)
    sig = dict(
        inputs=[{"name": "x", "shape": list(shp), "dtype": "f32"}],
        outputs=[
            {"name": f"f{i+1}", "shape": [c], "dtype": "f32"}
            for i, c in enumerate(features.FEATURES.channels)
        ],
    )
    lowered = jax.jit(features.extract).lower(
        jax.ShapeDtypeStruct(shp, jnp.float32)
    )
    return to_hlo_text(lowered), sig


# --------------------------------------------------------------------------
# Golden vectors: computed with jax here, re-checked bit-close by cargo
# tests against both the rust-native implementations and the loaded
# artifacts. Inputs are all derived from seeded numpy so both sides can
# regenerate them.
# --------------------------------------------------------------------------

def golden_schedule():
    ab = schedule.alpha_bars()
    sample_ts = [0, 1, 10, 100, 250, 500, 750, 998, 999]
    fast = schedule.ddim_grid(100)
    slow = schedule.stadi_slow_grid(fast, 4)
    return {
        "train_steps": SCHEDULE.train_steps,
        "beta_start": SCHEDULE.beta_start,
        "beta_end": SCHEDULE.beta_end,
        "alpha_bar_samples": {str(t): float(ab[t]) for t in sample_ts},
        "grid_m100": fast,
        "grid_m50": schedule.ddim_grid(50),
        "grid_slow_m100_w4": slow,
        "coeffs_m100_first8": [
            list(c) for c in schedule.grid_coefficients(fast)[:8]
        ],
        "coeffs_m100_last2": [
            list(c) for c in schedule.grid_coefficients(fast)[-2:]
        ],
    }


def golden_denoiser(params_flat):
    # Inputs from the cross-language PCG stream (compile.pcg mirrors
    # rust util::rng exactly), draw order: x, kv, cond.
    cfg = MODEL
    gen = pcg.NormalGen(1)
    h = 8
    x = gen.vec_f32(h * cfg.latent_w * cfg.latent_c).reshape(
        h, cfg.latent_w, cfg.latent_c
    )
    kv = gen.vec_f32(cfg.layers * cfg.tokens_full * 2 * cfg.dim).reshape(
        cfg.layers, cfg.tokens_full, 2 * cfg.dim
    )
    cond = gen.vec_f32(cfg.dim)
    eps, kvf = model.denoiser_patch(
        jnp.asarray(params_flat), jnp.asarray(x), jnp.asarray(kv),
        8, 500.0, jnp.asarray(cond), cfg, use_pallas=True,
    )
    eps = np.asarray(eps)
    kvf = np.asarray(kvf)
    return {
        "seed": 1,
        "h": h,
        "row_off": 8,
        "t": 500.0,
        "eps_first16": eps.reshape(-1)[:16].tolist(),
        "eps_sum": float(eps.sum()),
        "eps_abs_sum": float(np.abs(eps).sum()),
        "kv_first16": kvf.reshape(-1)[:16].tolist(),
        "kv_sum": float(kvf.sum()),
    }


def golden_trajectory(params_flat):
    """Sequential (Origin) DDIM trajectory, M=6 steps on the full latent.

    The rust integration test replays this with the h=32 artifact + the
    rust-native DDIM update and must match each step.
    """
    cfg = MODEL
    gen = pcg.NormalGen(11)
    x = gen.vec_f32(cfg.latent_h * cfg.latent_w * cfg.latent_c).reshape(
        cfg.latent_h, cfg.latent_w, cfg.latent_c
    )
    cond = gen.vec_f32(cfg.dim)
    grid = schedule.ddim_grid(6)
    coefs = schedule.grid_coefficients(grid)
    pf = jnp.asarray(params_flat)
    kv = jnp.zeros((cfg.layers, cfg.tokens_full, 2 * cfg.dim), jnp.float32)
    xs = jnp.asarray(x)
    steps = []
    for (t, (cx, ce)) in zip(grid, coefs):
        eps, kv = model.denoiser_patch(
            pf, xs, kv, 0, float(t), jnp.asarray(cond), cfg, use_pallas=True
        )
        xs = cx * xs + ce * eps
        arr = np.asarray(xs)
        steps.append({
            "t": t,
            "coef_x": cx,
            "coef_eps": ce,
            "x_first8": arr.reshape(-1)[:8].tolist(),
            "x_sum": float(arr.sum()),
        })
    return {"seed": 11, "grid": grid, "steps": steps}


def golden_features():
    cfg = MODEL
    gen = pcg.NormalGen(13)
    x = gen.vec_f32(cfg.latent_h * cfg.latent_w * cfg.latent_c).reshape(
        cfg.latent_h, cfg.latent_w, cfg.latent_c
    )
    f1, f2, f3 = features.extract(jnp.asarray(x))
    return {
        "seed": 13,
        "f1": np.asarray(f1).tolist(),
        "f2": np.asarray(f2).tolist(),
        "f3": np.asarray(f3).tolist(),
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--heights", default=None,
        help="comma-separated patch heights (default: all from config)",
    )
    ap.add_argument(
        "--skip-hlo", action="store_true",
        help="regenerate only params.bin + goldens + manifest, reusing "
             "the existing HLO files (weights are runtime inputs, so "
             "they do not affect the lowered programs)",
    )
    args = ap.parse_args()
    out = args.out_dir
    os.makedirs(out, exist_ok=True)
    os.makedirs(os.path.join(out, "golden"), exist_ok=True)

    cfg = MODEL
    heights = (
        [int(x) for x in args.heights.split(",")]
        if args.heights
        else list(cfg.patch_heights)
    )

    manifest = {
        "version": 1,
        "model": {
            "latent_h": cfg.latent_h,
            "latent_w": cfg.latent_w,
            "latent_c": cfg.latent_c,
            "patch": cfg.patch,
            "dim": cfg.dim,
            "heads": cfg.heads,
            "layers": cfg.layers,
            "mlp_ratio": cfg.mlp_ratio,
            "temb_dim": cfg.temb_dim,
            "row_granularity": cfg.row_granularity,
            "tokens_full": cfg.tokens_full,
            "param_count": model.param_count(cfg),
            "params_seed": PARAMS_SEED,
        },
        "schedule": {
            "train_steps": SCHEDULE.train_steps,
            "beta_start": SCHEDULE.beta_start,
            "beta_end": SCHEDULE.beta_end,
        },
        "param_spec": [
            {"name": n, "shape": list(s)} for n, s in model.param_spec(cfg)
        ],
        "artifacts": {},
    }

    print("[aot] writing params.bin")
    params_flat = model.init_params_flat(cfg)
    params_flat.tofile(os.path.join(out, "params.bin"))

    if args.skip_hlo:
        # Weights are runtime inputs: the lowered HLO is unchanged.
        # Reuse the existing artifact entries (and verify presence).
        print("[aot] --skip-hlo: reusing existing HLO artifacts")
        with open(os.path.join(out, "manifest.json")) as f:
            old = json.load(f)
        manifest["artifacts"] = old["artifacts"]
        for meta in manifest["artifacts"].values():
            path = os.path.join(out, meta["file"])
            assert os.path.getsize(path) == meta["bytes"], path
    else:
        for h in heights:
            print(f"[aot] lowering denoiser h={h}")
            text, sig = lower_denoiser(h)
            name = f"denoiser_h{h}.hlo.txt"
            meta = _write(os.path.join(out, name), text)
            manifest["artifacts"][f"denoiser_h{h}"] = {
                "file": name, **sig, **meta,
            }

        print("[aot] lowering ddim_update")
        text, sig = lower_ddim()
        meta = _write(os.path.join(out, "ddim_update.hlo.txt"), text)
        manifest["artifacts"]["ddim_update"] = {
            "file": "ddim_update.hlo.txt", **sig, **meta,
        }

        print("[aot] lowering features")
        text, sig = lower_features()
        meta = _write(os.path.join(out, "features.hlo.txt"), text)
        manifest["artifacts"]["features"] = {
            "file": "features.hlo.txt", **sig, **meta,
        }

    print("[aot] writing golden vectors")
    goldens = {
        "schedule.json": golden_schedule(),
        "denoiser.json": golden_denoiser(params_flat),
        "trajectory.json": golden_trajectory(params_flat),
        "features.json": golden_features(),
    }
    for name, data in goldens.items():
        with open(os.path.join(out, "golden", name), "w") as f:
            json.dump(data, f, indent=1)
        print(f"  wrote golden/{name}")

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("[aot] wrote manifest.json")


if __name__ == "__main__":
    main()
