"""Fixed random conv feature net for the LPIPS/FID proxy metrics.

Substitution (DESIGN.md §3): the paper scores images with pretrained
perceptual nets (LPIPS-VGG, InceptionV3 for FID). Those are unavailable
offline, so we use a *fixed random* 3-stage strided conv net — random
projections preserve the ordering of perturbation magnitudes, which is
what Table II's relative comparisons need. Weights are baked into the
HLO as constants (a few KiB) with a pinned seed so rust and python can
never disagree.

Output: per-stage global-average-pooled features
  f1 [16], f2 [32], f3 [64]  (LPIPS proxy uses all three stages,
  FID proxy uses f3 over an image set).
"""

import jax
import jax.numpy as jnp
import numpy as np

from .config import FEATURES, MODEL
from .kernels.ref import gelu


def _init_convs(cfg=FEATURES, model=MODEL):
    rng = np.random.default_rng(cfg.seed)
    chans = (model.latent_c,) + tuple(cfg.channels)
    ws = []
    for cin, cout in zip(chans[:-1], chans[1:]):
        # He-style scaling keeps activations O(1) through the stages.
        std = (2.0 / (cfg.kernel * cfg.kernel * cin)) ** 0.5
        ws.append(
            rng.normal(0.0, std, size=(cfg.kernel, cfg.kernel, cin, cout))
            .astype(np.float32)
        )
    return ws


_WEIGHTS = _init_convs()


def extract(x):
    """x: [H, W, C] latent -> (f1, f2, f3) pooled feature vectors."""
    h = x[None]  # NHWC
    feats = []
    for w in _WEIGHTS:
        h = jax.lax.conv_general_dilated(
            h,
            jnp.asarray(w),
            window_strides=(2, 2),
            padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        h = gelu(h)
        feats.append(jnp.mean(h, axis=(0, 1, 2)))
    return tuple(feats)
