"""Pure-jnp reference oracles for every Pallas kernel (L1).

These are the CORE correctness signal: each kernel in this package is
pytest-asserted allclose against the function of the same name here,
across shape/seed sweeps (hypothesis). The L2 model can be built against
either implementation (`use_pallas=` switch) so the whole forward pass
is differential-testable.
"""

import jax
import jax.numpy as jnp


def layernorm_modulate(x, scale, shift, eps: float = 1e-6):
    """adaLN-Zero style fused LN: normalize(x) * (1 + scale) + shift.

    x: [T, D]; scale, shift: [D] broadcast over tokens.
    """
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    xn = (x - mean) * jax.lax.rsqrt(var + eps)
    return xn * (1.0 + scale) + shift


def attention(q, k, v):
    """Multi-head attention, heads folded in the leading axis.

    q: [H, Tq, dh]; k, v: [H, Tk, dh] -> [H, Tq, dh].
    Numerically-stable softmax (max subtraction), f32 throughout.
    """
    dh = q.shape[-1]
    s = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(jnp.float32(dh))
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", p, v)


def gelu(x):
    """tanh-approx GELU (matches the Pallas kernel exactly)."""
    c = jnp.sqrt(jnp.float32(2.0 / jnp.pi))
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def mlp(x, w1, b1, w2, b2):
    """Fused transformer MLP: GELU(x @ w1 + b1) @ w2 + b2.

    x: [T, D]; w1: [D, F]; w2: [F, D].
    """
    h = gelu(x @ w1 + b1)
    return h @ w2 + b2


def ddim_update(x, eps, coef_x, coef_eps):
    """One DDIM / DPM-Solver-1 step (paper Eq. 3) in precomputed-
    coefficient form: x_next = coef_x * x + coef_eps * eps.

    The coefficients are produced by the noise schedule
    (compile.schedule.ddim_coefficients) so the kernel itself is a pure
    fused-multiply-add — this is also exactly what rust's
    model/sampler.rs implements natively.
    """
    return coef_x * x + coef_eps * eps
