"""Pallas multi-head attention kernel (L1 hot-spot).

TPU adaptation of the paper's CUDA attention (DESIGN.md §7): the grid
iterates over heads; each program instance holds one head's full
Q [Tq, dh] and KV [Tk, dh] tiles resident in VMEM (Tq <= 256, Tk = 256,
dh = 24 -> ~150 KiB, far under the ~16 MiB VMEM budget), and drives the
MXU with two dense matmuls around a numerically-stable softmax. The
HBM<->VMEM schedule DistriFusion expressed with threadblocks is expressed
here with the per-head BlockSpec index maps.

Lowered with interpret=True (CPU-PJRT cannot execute Mosaic custom
calls); see DESIGN.md §7 for the real-TPU VMEM/MXU estimates.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale):
    # One head per program instance. Block shapes carry a leading
    # singleton head axis; index [0] to get [T, dh] tiles.
    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p, v, preferred_element_type=jnp.float32)


@functools.partial(jax.named_call, name="pallas_attention")
def attention(q, k, v):
    """Multi-head attention. q: [H, Tq, dh]; k, v: [H, Tk, dh]."""
    h, tq, dh = q.shape
    _, tk, _ = k.shape
    scale = 1.0 / (dh ** 0.5)
    kernel = functools.partial(_attn_kernel, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, tq, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, tk, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, tk, dh), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, dh), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, tq, dh), jnp.float32),
        interpret=True,
    )(q, k, v)
