"""L1 Pallas kernels + pure-jnp reference oracles (ref.py)."""

from . import attention, ddim, layernorm, mlp, ref  # noqa: F401
