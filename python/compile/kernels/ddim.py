"""Pallas DDIM / DPM-Solver-1 update kernel (L1, paper Eq. 3).

x_next = coef_x * x + coef_eps * eps, with the two scalar coefficients
precomputed from the noise schedule (compile.schedule.ddim_coefficients)
and passed as (1, 1) SMEM-style operands. A pure fused-multiply-add over
the latent; tiled over rows so it streams through VMEM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ddim_kernel(x_ref, eps_ref, cx_ref, ce_ref, o_ref):
    o_ref[...] = cx_ref[0, 0] * x_ref[...] + ce_ref[0, 0] * eps_ref[...]


def ddim_update(x, eps, coef_x, coef_eps):
    """x, eps: [H, W, C]; coef_x, coef_eps: scalars (python float or 0-d)."""
    h, w, c = x.shape
    cx = jnp.asarray(coef_x, jnp.float32).reshape(1, 1)
    ce = jnp.asarray(coef_eps, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _ddim_kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, w, c), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, w, c), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, w, c), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w, c), jnp.float32),
        interpret=True,
    )(x, eps, cx, ce)
