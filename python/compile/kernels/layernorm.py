"""Pallas fused LayerNorm + adaLN modulation kernel (L1).

Computes normalize(x) * (1 + scale) + shift in one VMEM-resident pass.
Token rows are tiled across the grid so arbitrarily tall patches stream
through a fixed-size VMEM tile (TILE_T tokens x D floats).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_T = 16


def _ln_kernel(x_ref, scale_ref, shift_ref, o_ref, *, eps):
    x = x_ref[...]
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    xn = (x - mean) * jax.lax.rsqrt(var + eps)
    o_ref[...] = xn * (1.0 + scale_ref[...]) + shift_ref[...]


def layernorm_modulate(x, scale, shift, eps: float = 1e-6):
    """x: [T, D]; scale, shift: [D]. T must be a multiple of TILE_T or
    smaller than it (single tile)."""
    t, d = x.shape
    tile = min(TILE_T, t)
    assert t % tile == 0, (t, tile)
    kernel = functools.partial(_ln_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(t // tile,),
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), jnp.float32),
        interpret=True,
    )(x, scale, shift)
