"""Pallas fused transformer-MLP kernel (L1): GELU(x@W1+b1)@W2+b2.

Token rows are tiled over the grid; both weight matrices stay resident
in VMEM across tiles (D=96, F=384 -> W1+W2 ~ 288 KiB), so each tile
costs two MXU matmuls and one VPU GELU with no HBM round-trip for the
intermediate [tile, F] activation — the fusion the paper gets from
cuDNN/AMP is expressed structurally here.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_T = 16


def _gelu(x):
    c = jnp.sqrt(jnp.float32(2.0 / jnp.pi))
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def _mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...]
    h = _gelu(
        jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
        + b1_ref[...]
    )
    o_ref[...] = (
        jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)
        + b2_ref[...]
    )


def mlp(x, w1, b1, w2, b2):
    """x: [T, D]; w1: [D, F]; b1: [F]; w2: [F, D]; b2: [D]."""
    t, d = x.shape
    f = w1.shape[1]
    tile = min(TILE_T, t)
    assert t % tile == 0, (t, tile)
    return pl.pallas_call(
        _mlp_kernel,
        grid=(t // tile,),
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((d, f), lambda i: (0, 0)),
            pl.BlockSpec((f,), lambda i: (0,)),
            pl.BlockSpec((f, d), lambda i: (0, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), jnp.float32),
        interpret=True,
    )(x, w1, b1, w2, b2)
