"""Diffusion noise schedule + DDIM timestep grids (build-time mirror).

This module is the python twin of rust `model/schedule.rs`; `aot.py`
dumps golden vectors from here and cargo tests assert the rust
implementation matches to f32 tolerance, so the two sides can never
drift. All math is float64 internally, surfaced as float32 (matching
rust, which computes in f64 and stores f32).

Conventions (paper §II-A):
  beta_t: scaled-linear (Stable-Diffusion style) over `train_steps`.
  alpha_bar_t = prod_{s<=t}(1 - beta_s)            (cumulative)
  alpha_t (paper) = sqrt(alpha_bar_t),  sigma_t = sqrt(1 - alpha_bar_t)
  DDIM (eta=0) step t -> s (s < t):
    x_s = sqrt(ab_s/ab_t) * x_t
        + (sqrt(1-ab_s) - sqrt(ab_s/ab_t) * sqrt(1-ab_t)) * eps
  which is Eq. 3 with coefficients precomputed (coef_x, coef_eps).
"""

import numpy as np

from .config import SCHEDULE


def betas(cfg=SCHEDULE):
    """Scaled-linear betas: linspace in sqrt-space, squared."""
    return (
        np.linspace(
            cfg.beta_start ** 0.5,
            cfg.beta_end ** 0.5,
            cfg.train_steps,
            dtype=np.float64,
        )
        ** 2
    )


def alpha_bars(cfg=SCHEDULE):
    """alpha_bar indexed by t in [0, train_steps); ab[t] = prod(1-beta)."""
    return np.cumprod(1.0 - betas(cfg))


def ddim_grid(m: int, cfg=SCHEDULE):
    """Leading-spaced DDIM grid of m timesteps, decreasing.

    grid[k] = floor(k * T / m) for k = m-1 .. 0, i.e. the standard
    `leading` spacing. The final update goes grid[-1] -> "clean" (t=-1,
    alpha_bar=1).
    """
    t = cfg.train_steps
    return [(k * t) // m for k in range(m - 1, -1, -1)]


def stadi_slow_grid(fast_grid, warmup: int):
    """Slow-device grid per STADI temporal adaptation (paper §III-C).

    Shares the first `warmup` timesteps with the fast grid, then takes
    every 2nd point of the remainder — the LCM-minimizing 2:1
    quantization of Eq. 4 (M_slow = warmup + (M_fast - warmup)/2). The
    tail is kept aligned so both grids terminate at fast_grid[-1]:
    we take the *odd* offsets of the remainder when its length is even,
    which always includes the last point.
    """
    rest = fast_grid[warmup:]
    assert len(rest) % 2 == 0, "M_base - M_warmup must be even"
    return list(fast_grid[:warmup]) + list(rest[1::2])


def ddim_coefficients(t_from: int, t_to: int, cfg=SCHEDULE):
    """(coef_x, coef_eps) for one DDIM step t_from -> t_to.

    t_to == -1 denotes the final step to the clean sample
    (alpha_bar = 1, sigma = 0).
    """
    ab = alpha_bars(cfg)
    ab_t = ab[t_from]
    ab_s = 1.0 if t_to < 0 else ab[t_to]
    coef_x = np.sqrt(ab_s / ab_t)
    coef_eps = np.sqrt(1.0 - ab_s) - coef_x * np.sqrt(1.0 - ab_t)
    return float(coef_x), float(coef_eps)


def grid_coefficients(grid, cfg=SCHEDULE):
    """Coefficient pairs for a full decreasing grid, ending at clean."""
    pairs = []
    for i, t in enumerate(grid):
        t_to = grid[i + 1] if i + 1 < len(grid) else -1
        pairs.append(ddim_coefficients(t, t_to, cfg))
    return pairs
