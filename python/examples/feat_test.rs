fn main() {
    let m = stadi::runtime::Manifest::load("artifacts").unwrap();
    let rt = stadi::runtime::Runtime::new(m).unwrap();
    let mut g = stadi::util::rng::NormalGen::new(13);
    let x = stadi::runtime::Tensor::new(vec![32,32,4], g.vec_f32(4096)).unwrap();
    let (f1,f2,f3) = rt.features(&x).unwrap();
    println!("f1[..4]={:?}", &f1[..4]);
    println!("f2[..4]={:?}", &f2[..4]);
    println!("f3[..4]={:?}", &f3[..4]);
}
