#!/usr/bin/env bash
# CI gate: formatting, lints, build, tests — exits nonzero on the
# first failure (set -e). Run from the repo root (or anywhere — the
# script cd's to the rust crate). .github/workflows/ci.yml runs this
# on every push/PR.
#
#   scripts/check.sh            # default (offline, stub runtime)
#   scripts/check.sh --xla      # also check the real-PJRT feature
#                               # (requires the xla crate; see
#                               # rust/Cargo.toml)

set -euo pipefail
cd "$(dirname "$0")/../rust"

FEATURES=()
if [[ "${1:-}" == "--xla" ]]; then
    FEATURES=(--features xla-backend)
fi

echo "== cargo fmt --check"
cargo fmt --check

echo "== cargo clippy --all-targets -- -D warnings"
cargo clippy --all-targets "${FEATURES[@]}" -- -D warnings

echo "== cargo build --release"
cargo build --release "${FEATURES[@]}"

echo "== cargo test -q"
cargo test -q "${FEATURES[@]}"

echo "ok"
