#!/usr/bin/env bash
# CI gate: formatting, lints, build, tests, feature-surface and doc
# checks — exits nonzero on the first failure (set -e). Run from the
# repo root (or anywhere — the script cd's to the rust crate).
# .github/workflows/ci.yml runs this on every push/PR.
#
#   scripts/check.sh            # default (offline, stub runtime)
#   scripts/check.sh --xla      # run the full suite under the
#                               # real-PJRT feature (requires the real
#                               # xla crate; see rust/Cargo.toml)
#
# The default run still *compile-gates* the xla-backend feature
# against the offline API stub in rust/xla-stub — API-surface
# regressions behind the feature fail fast without registry access —
# and builds the docs (`cargo doc --no-deps` with warnings denied) so
# broken intra-doc links fail the gate too.

set -euo pipefail
cd "$(dirname "$0")/../rust"

FEATURES=()
if [[ "${1:-}" == "--xla" ]]; then
    FEATURES=(--features xla-backend)
fi

echo "== cargo fmt --check"
cargo fmt --check

echo "== cargo clippy --all-targets -- -D warnings"
cargo clippy --all-targets "${FEATURES[@]}" -- -D warnings

echo "== cargo build --release"
cargo build --release "${FEATURES[@]}"

echo "== cargo test -q"
cargo test -q "${FEATURES[@]}"

echo "== cargo check --features xla-backend (API-surface gate)"
cargo check --features xla-backend

echo "== cargo doc --no-deps"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "ok"
