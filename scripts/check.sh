#!/usr/bin/env bash
# CI gate: formatting, lints, build, tests, feature-surface and doc
# checks — exits nonzero on the first failure (set -e). Run from the
# repo root (or anywhere — the script cd's to the rust crate).
# .github/workflows/ci.yml runs this on every push/PR.
#
#   scripts/check.sh            # default (offline, stub runtime)
#   scripts/check.sh --xla      # run the full suite under the
#                               # real-PJRT feature (requires the real
#                               # xla crate; see rust/Cargo.toml)
#
# The default run executes the test suite TWICE — once with default
# features and once with `--features xla-backend` against the offline
# API stub in rust/xla-stub — so feature-gated code (the resolution
# plumbing included) is compiled AND its always-run tests executed in
# both configurations; it cannot rot behind the gate. Docs build with
# warnings denied so broken intra-doc links fail too.
#
# Property tests: QUICKCHECK_SEED seeds the `util::proptest` harness
# (defaults to today's UTC date, so every day explores a fresh slice
# of the input space). A failing property prints the reproducing seed
# — re-run with `QUICKCHECK_SEED=<seed> cargo test <name>`.

set -euo pipefail
ROOT="$(cd "$(dirname "$0")/.." && pwd)"
cd "$ROOT/rust"

QUICKCHECK_SEED="${QUICKCHECK_SEED:-$(date -u +%Y%m%d)}"
export QUICKCHECK_SEED
echo "== QUICKCHECK_SEED=$QUICKCHECK_SEED"

FEATURES=()
if [[ "${1:-}" == "--xla" ]]; then
    FEATURES=(--features xla-backend)
fi

echo "== cargo fmt --check"
cargo fmt --check

echo "== cargo clippy --all-targets -- -D warnings"
cargo clippy --all-targets "${FEATURES[@]}" -- -D warnings

echo "== cargo build --release"
cargo build --release "${FEATURES[@]}"

echo "== cargo test -q"
cargo test -q "${FEATURES[@]}"

if [[ ${#FEATURES[@]} -eq 0 ]]; then
    echo "== cargo test -q --features xla-backend (offline API stub)"
    cargo test -q --features xla-backend
else
    echo "== cargo check (default features)"
    cargo check
fi

# Retry-free flake gate: the drift-injection tests must be a pure
# function of their inputs. Run them twice in one job, each time
# dumping the DES comparison's stats JSON, and diff the two dumps —
# any nondeterminism (wall-clock leakage, map-order iteration,
# uninitialized state) fails CI here, with zero retries to hide it.
echo "== drift determinism gate (run twice, diff pinned stats JSON)"
DRIFT_A="$(mktemp)"
DRIFT_B="$(mktemp)"
BENCH_TMP="$(mktemp -d)"
trap 'rm -f "$DRIFT_A" "$DRIFT_B"; rm -rf "$BENCH_TMP"' EXIT
STADI_REPLAN_STATS_OUT="$DRIFT_A" \
    cargo test -q "${FEATURES[@]}" --test integration_replan
STADI_REPLAN_STATS_OUT="$DRIFT_B" \
    cargo test -q "${FEATURES[@]}" --test integration_replan
diff -u "$DRIFT_A" "$DRIFT_B"
echo "   drift stats identical across runs"

# Displaced-halo quality gate: the PSNR/SSIM/LPIPS floors and the
# budget-0 bit-identity property must hold in BOTH feature configs —
# the staleness path crosses the executor/runtime boundary, so it
# must not rot behind the xla-backend gate either.
echo "== displaced-halo quality gate (default + xla-backend stub)"
cargo test -q --test integration_halo
cargo test -q --features xla-backend --test integration_halo

# Cross-request batching gate: the fused-vs-solo byte-identity pins,
# the serve-worker admission window, and the DES frontier claims must
# hold in BOTH feature configs (the fused path crosses the
# executor/runtime boundary like the halo path does).
echo "== cross-request batching gate (default + xla-backend stub)"
cargo test -q --test integration_batch
cargo test -q --features xla-backend --test integration_batch

# Federation gate: equal-speed migration byte-identity, spill-over
# ledger pins, the default-config bit-exactness claim, and the
# DES-vs-committed-artifact match must hold in BOTH feature configs
# (the envelope resume path crosses the executor/runtime boundary
# like the halo and batching paths do).
echo "== federation gate (default + xla-backend stub)"
cargo test -q --test integration_federation
cargo test -q --features xla-backend --test integration_federation

# Degradation gate: the scenario-storm suite — strictly-more-deadlines
# at overload vs the committed BENCH_degradation.json, the ladder-off
# bit-exactness pin, the replan-precedence rule, and the
# QUICKCHECK_SEED ladder properties — must hold in BOTH feature
# configs (the degraded executor crosses the session/runtime boundary
# like the paths above).
echo "== graceful degradation gate (default + xla-backend stub)"
cargo test -q --test integration_degrade
cargo test -q --features xla-backend --test integration_degrade

# Connection-scale gate: the adversarial-client suite (slow-loris,
# non-reading client, mid-line half-close, oversized line, pipelined
# reordering), the 512-client event-loop smoke, the event-vs-threads
# byte-identity pin, and the table-full zero-drop pin must hold in
# BOTH feature configs (the serve front-end is feature-independent,
# but this keeps it from rotting behind the gate like the others).
echo "== connection-scale gate (default + xla-backend stub)"
cargo test -q --test integration_connscale
cargo test -q --features xla-backend --test integration_connscale

# The committed perf-trajectory artifacts at the repo root must each
# carry the displaced-halo pricing ("halo" key) — a re-anchor that
# regenerates them without it silently drops the perf history this
# PR pinned. scripts/gen_bench_artifacts.py regenerates them.
# BENCH_batching.json is additionally required by name: it is the
# throughput-vs-latency frontier tests/integration_batch.rs pins
# against the in-process sweep. BENCH_federation.json likewise: it is
# the deadline-hit frontier tests/integration_federation.rs pins.
# BENCH_degradation.json likewise: the quality-vs-deadline frontier
# tests/integration_degrade.rs pins. BENCH_protocol.json likewise:
# the lazy-parse cost model whose >= 5x v2 speedup the generator
# asserts (benches/bench_protocol.rs re-checks it inline).
echo "== committed BENCH artifacts carry halo pricing"
for req in BENCH_batching.json BENCH_federation.json \
           BENCH_degradation.json BENCH_protocol.json; do
    if [[ ! -e "$ROOT/$req" ]]; then
        echo "error: $req missing at repo root" \
             "(regenerate with scripts/gen_bench_artifacts.py)" >&2
        exit 1
    fi
done
found=0
for f in "$ROOT"/BENCH_*.json; do
    [[ -e "$f" ]] || continue
    found=1
    if ! grep -q '"halo"' "$f"; then
        echo "error: $(basename "$f") is missing the \"halo\" key" >&2
        exit 1
    fi
    echo "   $(basename "$f") ok"
done
if [[ $found -eq 0 ]]; then
    echo "error: no committed BENCH_*.json artifacts at repo root" >&2
    exit 1
fi

# Artifact drift gate: every committed BENCH_*.json must re-derive,
# field for field, from the analytical generator at HEAD. A code edit
# that shifts any pinned number without regenerating the artifacts
# (or a hand-edited artifact) fails here, naming the first divergent
# field path.
echo "== BENCH artifact drift gate (regenerate into tmp, compare)"
python3 "$ROOT/scripts/gen_bench_artifacts.py" --out "$BENCH_TMP" \
    > /dev/null
for f in "$ROOT"/BENCH_*.json; do
    name="$(basename "$f")"
    if [[ ! -e "$BENCH_TMP/$name" ]]; then
        echo "error: $name is committed but no longer emitted by" \
             "scripts/gen_bench_artifacts.py" >&2
        exit 1
    fi
    python3 - "$f" "$BENCH_TMP/$name" <<'PY'
import json, sys

def walk(a, b, path):
    if type(a) is not type(b):
        sys.exit(f"drift at {path}: {type(a).__name__} vs "
                 f"{type(b).__name__}")
    if isinstance(a, dict):
        if list(a) != list(b):
            sys.exit(f"drift at {path}: keys {list(a)} vs {list(b)}")
        for k in a:
            walk(a[k], b[k], f"{path}.{k}")
    elif isinstance(a, list):
        if len(a) != len(b):
            sys.exit(f"drift at {path}: len {len(a)} vs {len(b)}")
        for i, (x, y) in enumerate(zip(a, b)):
            walk(x, y, f"{path}[{i}]")
    elif isinstance(a, float) or isinstance(b, float):
        if abs(a - b) > 1e-9:
            sys.exit(f"drift at {path}: {a!r} vs {b!r}")
    elif a != b:
        sys.exit(f"drift at {path}: {a!r} vs {b!r}")

committed, fresh = sys.argv[1], sys.argv[2]
with open(committed) as fh:
    a = json.load(fh)
with open(fresh) as fh:
    b = json.load(fh)
walk(a, b, "$")
PY
    echo "   $name re-derives cleanly"
done

echo "== cargo doc --no-deps"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "ok"
