#!/usr/bin/env python3
"""Seed the committed BENCH_*.json perf-trajectory artifacts.

Deterministic mirror of the Rust timeline model (coordinator/timeline.rs),
the Eq. 4/5 planner shape (sched/temporal.rs, sched/spatial.rs) and the
alpha+beta comm costs (comm.rs), evaluated with the *uncalibrated* cost
model (device.rs: fixed 4 ms, 1.2 ms/row) on the stub-backend geometry.

`cargo bench` regenerates richer, measured sweeps into bench_out/; this
script exists so the repo-root artifacts can be (re)produced on a
machine without the Rust toolchain and so the committed numbers are
auditable arithmetic, not snapshots of one host's wall clock. Every
emitted file carries a `source` field saying exactly that, and a `halo`
key (sync-vs-displaced pricing) that scripts/check.sh schema-checks.

Usage: python3 scripts/gen_bench_artifacts.py [--out DIR]
(default DIR is the repo root, i.e. the parent of this script's
directory; scripts/check.sh uses --out to re-derive the committed
artifacts into a scratch dir and diff them field by field)
"""

import json
import math
import os
import sys

# --- cost model (device.rs CostModel::uncalibrated) -------------------
FIXED_S = 4e-3
PER_ROW_S = 1.2e-3


def step_time(rows, v):
    return (FIXED_S + PER_ROW_S * rows) / v


# --- comm (comm.rs; PadAllGather strategy only, the default) ----------
def p2p(comm, nbytes):
    return comm["latency_s"] + nbytes / comm["bandwidth_bytes_per_s"]


def all_gather(comm, sizes):
    if len(sizes) <= 1:
        return 0.0
    return (len(sizes) - 1) * p2p(comm, max(sizes))


# displaced_exchange_cost == all_gather_cost (pinned in comm.rs tests):
# the bytes are identical, only the *charging* (blocking vs overlapped)
# differs, which is the timeline's job.
displaced_exchange = all_gather

DEFAULT_COMM = {"latency_s": 20e-6, "bandwidth_bytes_per_s": 20e9}
SLOW_COMM = {"latency_s": 0.02, "bandwidth_bytes_per_s": 2e7}

# --- stub model geometry (runtime/stubgen.rs) -------------------------
LATENT_W = 32
LATENT_C = 4
PATCH = 2
DIM = 16
LAYERS = 2
GRANULARITY = 4


def x_bytes(rows):
    return rows * LATENT_W * LATENT_C * 4


def kv_bytes(rows):
    tokens = (rows // PATCH) * (LATENT_W // PATCH)
    return LAYERS * tokens * 2 * DIM * 4


# --- Eq. 4 temporal classes (sched/temporal.rs) -----------------------
def assign_steps(speeds, m_base, m_warmup, a=0.75, b=0.25):
    v_max = max(speeds)
    half = m_warmup + (m_base - m_warmup) // 2
    out = []
    for v in speeds:
        if v <= b * v_max:
            out.append(("excluded", 0))
        elif v <= a * v_max:
            out.append(("half", half))
        else:
            out.append(("full", m_base))
    return out


# --- Eq. 5 largest-remainder mend (sched/spatial.rs) ------------------
def mend_rows(speeds, assign, total_rows, gran=GRANULARITY):
    gt = total_rows // gran
    rates = [
        0.0 if a[0] == "excluded" else v / a[1]
        for v, a in zip(speeds, assign)
    ]
    s = sum(rates)
    ideal = [r / s * gt for r in rates]
    included = [i for i, a in enumerate(assign) if a[0] != "excluded"]
    granules = [0] * len(speeds)
    remainders = []
    used = 0
    for i in included:
        g = max(int(math.floor(ideal[i])), 1)
        granules[i] = g
        used += g
        remainders.append((ideal[i] - math.floor(ideal[i]), i))
    if used < gt:
        remainders.sort(key=lambda t: -t[0])
        k = 0
        while used < gt:
            granules[remainders[k % len(remainders)][1]] += 1
            used += 1
            k += 1
    while used > gt:
        mi = max(included, key=lambda i: granules[i])
        granules[mi] -= 1
        used -= 1
    return [g * gran for g in granules]


# --- plan sync-interval structure (sched/plan.rs assemble) ------------
def intervals_for(assign, m_base, m_warmup):
    """Per sync interval: ([steps per device], any_warmup_step).

    Mirrors the grid-intersection rule for the two shapes this script
    uses: all-Full (every step syncs) and Full+Half (fast singles for
    the first m_warmup-1 intervals, then pairs, final step alone).
    """
    classes = [a[0] for a in assign]
    any_half = "half" in classes
    if not any_half:
        return [
            ([1 if c == "full" else 0 for c in classes], i < m_warmup)
            for i in range(m_base)
        ]
    n = m_warmup + (m_base - m_warmup) // 2
    out = []
    for i in range(n):
        if i < m_warmup - 1:
            fast = 1
        elif i == n - 1:
            fast = 1
        else:
            fast = 2
        steps = [
            (fast if c == "full" else (1 if c == "half" else 0))
            for c in classes
        ]
        out.append((steps, i < m_warmup))
    return out


def warmup_sync_count(intervals):
    return sum(1 for _, w in intervals if w)


# --- timeline (coordinator/timeline.rs simulate_span) -----------------
def simulate(rows, eff_speeds, intervals, comm, budget=None):
    """budget=None -> HaloMode::Sync; else Displaced{max_staleness}."""
    included = [i for i, r in enumerate(rows) if r > 0]
    xs = [x_bytes(rows[i]) for i in included]
    kvs = [kv_bytes(rows[i]) for i in included]
    n_syncs = len(intervals)
    wsc = warmup_sync_count(intervals)

    def fallback(si):
        return (
            budget is None
            or budget == 0
            or si < budget
            or si < wsc
            or si + 1 >= n_syncs
        )

    now = comm_s = 0.0
    busy = [0.0] * len(rows)
    overlap = [0.0] * len(rows)
    debts = []  # [deadline, remaining]
    disp = fb = 0
    for si, (steps, is_warmup) in enumerate(intervals):
        arrivals = []
        for di in included:
            t = steps[di] * step_time(rows[di], eff_speeds[di])
            busy[di] += t
            arrivals.append((di, t))
        min_compute = min(t for _, t in arrivals)
        outstanding = sum(r for _, r in debts)
        if outstanding > 0.0:
            for di, t in arrivals:
                overlap[di] += min(t, outstanding)
        drain = min_compute
        for e in debts:
            if drain <= 0.0:
                break
            d = min(e[1], drain)
            e[1] -= d
            drain -= d
        last = si == n_syncs - 1
        unmasked = 0.0
        kept = []
        for deadline, remaining in debts:
            if remaining <= 0.0:
                continue
            if deadline <= si or last:
                unmasked += remaining
                continue
            kept.append([deadline, remaining])
        debts = kept
        comm_s += unmasked
        barrier = max(t for _, t in arrivals)
        if fallback(si):
            fb += 1
            x = all_gather(comm, xs)
            comm_s += x
            ti = barrier + unmasked + x
            if is_warmup or last:
                kv = all_gather(comm, kvs)
                comm_s += kv
                ti += kv
            else:
                debts.append([si + 1, all_gather(comm, kvs)])
            now += ti
        else:
            disp += 1
            debts.append(
                [
                    si + budget,
                    displaced_exchange(comm, xs)
                    + displaced_exchange(comm, kvs),
                ]
            )
            now += barrier + unmasked
    return {
        "total_s": now,
        "comm_s": comm_s,
        "displaced": disp,
        "fallback": fb,
        "overlap_s": [overlap[i] for i in included],
    }


def plan_and_simulate(speeds, eff, m_base, m_warmup, total_rows, comm,
                      budget=None):
    assign = assign_steps(speeds, m_base, m_warmup)
    rows = mend_rows(speeds, assign, total_rows)
    iv = intervals_for(assign, m_base, m_warmup)
    out = simulate(rows, eff, iv, comm, budget)
    out["rows"] = rows
    out["sync_points"] = len(iv)
    return out


# --- cross-request batching frontier (serve/sim.rs mirror) ------------
def batch_group_compatible(arrivals, window_s, max_batch):
    """Mirror of serve::batch::group_compatible (greedy, in order)."""
    max_batch = max(max_batch, 1)
    groups = []
    taken = [False] * len(arrivals)
    for i in range(len(arrivals)):
        if taken[i]:
            continue
        taken[i] = True
        t0, key = arrivals[i]
        group = [i]
        for j in range(i + 1, len(arrivals)):
            if len(group) >= max_batch:
                break
            t, k = arrivals[j]
            if taken[j] or k != key:
                continue
            if t > t0 + window_s:
                continue
            taken[j] = True
            group.append(j)
        groups.append(group)
    return groups


def batch_percentile(xs, q):
    """Mirror of util::stats::percentile (linear interpolation)."""
    if not xs:
        return 0.0
    s = sorted(xs)
    pos = (q / 100.0) * (len(s) - 1)
    lo = int(math.floor(pos))
    hi = int(math.ceil(pos))
    if lo == hi:
        return s[lo]
    w = pos - lo
    return s[lo] * (1.0 - w) + s[hi] * w


def batch_serve_groups(arrivals, groups, servers, service, deadline_s):
    """Mirror of serve::sim::serve_groups (FIFO by ready time)."""
    free = [0.0] * max(servers, 1)
    sojourns = [0.0] * len(arrivals)
    makespan = 0.0
    for ready, members in groups:
        k = 0
        best = free[0]
        for i, f in enumerate(free):
            if f < best:
                k = i
                best = f
        start = max(ready, best)
        finish = start + service(len(members))
        free[k] = finish
        makespan = max(makespan, finish)
        for m in members:
            sojourns[m] = finish - arrivals[m]
    hits = sum(1 for s in sojourns if s <= deadline_s)
    n = len(sojourns)
    return {
        "throughput_rps": n / makespan if makespan > 0.0 else 0.0,
        "mean_sojourn_s": sum(sojourns) / n if n else 0.0,
        "p95_sojourn_s": batch_percentile(sojourns, 95.0),
        "deadline_hit_rate": hits / n if n else 1.0,
        "mean_group": n / max(len(groups), 1),
    }


def batch_frontier():
    """Mirror of serve::sim::simulate_batch_frontier on the
    BatchFrontierConfig::stub_fixture() constants: 8 steps on a 2-gang
    fleet over the slow interconnect, 16 rows per device per member.
    A fused session of B pays fixed + comm once and the per-row work B
    times; tests/integration_batch.rs pins this output against the
    in-process Rust sweep."""
    steps = 8.0
    per_sync_comm = p2p(SLOW_COMM, x_bytes(16)) + p2p(
        SLOW_COMM, kv_bytes(16)
    )
    servers = 2
    max_batch = 4
    window_s = 0.25
    session_fixed_s = steps * (0.004 + per_sync_comm)
    per_member_s = steps * 0.0012 * 16.0
    deadline_s = 4.0
    n_requests = 240
    load_multiples = [0.5, 1.0, 2.0, 4.0, 6.0, 8.0, 10.0]

    def service(members):
        return session_fixed_s + members * per_member_s

    key_a = (32, 32, 8, 2, 0)
    key_b = (48, 32, 8, 2, 0)
    cap = servers / service(1)
    points = []
    for load_x in load_multiples:
        rate = load_x * cap
        arrivals = [
            (i / rate, key_b if i % 3 == 2 else key_a)
            for i in range(n_requests)
        ]
        times = [t for t, _ in arrivals]
        solo = [(t, [i]) for i, t in enumerate(times)]
        disjoint = batch_serve_groups(
            times, solo, servers, service, deadline_s
        )
        fused = []
        for g in batch_group_compatible(arrivals, window_s, max_batch):
            if len(g) == max_batch:
                ready = times[g[-1]]
            else:
                ready = times[g[0]] + window_s
            fused.append((ready, g))
        fused.sort(key=lambda e: e[0])
        batched = batch_serve_groups(
            times, fused, servers, service, deadline_s
        )
        points.append(
            {
                "load_x": load_x,
                "rate_rps": rate,
                "disjoint": disjoint,
                "batched": batched,
            }
        )
    return {
        "servers": servers,
        "max_batch": max_batch,
        "window_s": window_s,
        "session_fixed_s": session_fixed_s,
        "per_member_s": per_member_s,
        "deadline_s": deadline_s,
        "halo": "shared-per-session",
        "points": points,
    }


# --- federated serving DES (serve/sim.rs federation mirror) -----------
FED_CFG = {
    "nodes": 4,
    "servers_per_node": 2,
    "service_s": 1.0,
    "segments": 4,
    "deadline_s": 3.0,
    "migration_s": 0.05,
    "busy_wait_s": 1.0,
    "spike_speed": 0.1,
    "window_s": 5.0,
    "n_requests": 240,
    "load_multiples": [0.5, 1.0, 1.5, 2.0, 2.5],
}

FED_TRACES = ["bursty", "diurnal", "flash"]


def fed_arrivals(trace, rate, n):
    """Mirror of serve::sim::federation_arrivals (closed-form)."""
    out = []
    if trace == "bursty":
        for i in range(n):
            out.append((i // 6) * (6.0 / rate))
    elif trace == "diurnal":
        mult = [0.5, 1.5, 2.0, 1.0]
        t = 0.0
        for i in range(n):
            q = min(i * 4 // n, 3)
            t += 1.0 / (rate * mult[q])
            out.append(t)
    elif trace == "flash":
        t = 0.0
        for i in range(n):
            dt = 1.0 / (3.0 * rate) if n // 3 <= i < n // 2 else 1.0 / rate
            t += dt
            out.append(t)
    else:
        raise ValueError(f"unknown federation trace {trace!r}")
    return out


def fed_percentile(xs, p):
    """Mirror of serve::sim::fed_percentile — same interpolation form
    as batch_percentile but written `lo + (hi - lo) * w`, kept digit
    for digit with the Rust side (the two forms differ in last-ulp)."""
    if not xs:
        return 0.0
    s = sorted(xs)
    rank = p / 100.0 * (len(s) - 1)
    lo = math.floor(rank)
    hi = math.ceil(rank)
    return s[lo] + (s[hi] - s[lo]) * (rank - lo)


def fed_speed(cfg, node, t):
    """Rotating brownout: floor(t / window) % nodes runs slowed."""
    if math.floor(t / cfg["window_s"]) % cfg["nodes"] == node:
        return cfg["spike_speed"]
    return 1.0


def fed_run(cfg, arrivals, mode):
    """Mirror of serve::sim::fed_run, operation for operation.

    mode is "single" | "fed_nomig" | "fed_mig". Admission probes queue
    depth plus one service at the node's *current* speed (no future
    knowledge of the brownout rotation); migration is a deadline
    rescue onto an idle full-speed sibling, one hop max.
    """
    n_nodes = 1 if mode == "single" else cfg["nodes"]
    free = [[0.0] * cfg["servers_per_node"] for _ in range(n_nodes)]
    seg_work = cfg["service_s"] / cfg["segments"]

    def min_server(nd):
        k, best = 0, free[nd][0]
        for i, f in enumerate(free[nd]):
            if f < best:
                k, best = i, f
        return k, best

    sojourns = []
    migrations = spills = 0
    last_finish = 0.0
    for i, a in enumerate(arrivals):
        if mode == "single":
            node = 0
        else:
            home = i % cfg["nodes"]

            def fin_est(nd):
                return (
                    max(min_server(nd)[1], a)
                    + cfg["service_s"] / fed_speed(cfg, nd, a)
                )

            if fin_est(home) - a > cfg["busy_wait_s"]:
                chosen, best = home, fin_est(home)
                for nd in range(cfg["nodes"]):
                    if fin_est(nd) < best:
                        chosen, best = nd, fin_est(nd)
                if chosen != home:
                    spills += 1
                node = chosen
            else:
                node = home
        cur_k, f0 = min_server(node)
        cur_node = node
        t = max(a, f0)
        migrated = False
        for s in range(cfg["segments"]):
            t += seg_work / fed_speed(cfg, cur_node, t)
            if mode == "fed_mig" and not migrated and s + 1 < cfg["segments"]:
                spd_now = fed_speed(cfg, cur_node, t)
                if spd_now < 1.0:
                    remaining = (cfg["segments"] - s - 1) * seg_work
                    stay = t + remaining / spd_now
                    best = None
                    for nd in range(cfg["nodes"]):
                        if nd == cur_node or fed_speed(cfg, nd, t) < 1.0:
                            continue
                        kk, fdest = min_server(nd)
                        if fdest > t + cfg["migration_s"]:
                            continue
                        fin = max(t + cfg["migration_s"], fdest) + remaining
                        if best is None or fin < best[0]:
                            best = (fin, nd, kk)
                    deadline = a + cfg["deadline_s"]
                    if best is not None and stay > deadline \
                            and best[0] <= deadline:
                        fin, nd, kk = best
                        free[cur_node][cur_k] = t
                        t = max(t + cfg["migration_s"], free[nd][kk])
                        cur_node, cur_k = nd, kk
                        migrated = True
                        migrations += 1
        free[cur_node][cur_k] = t
        sojourns.append(t - a)
        if t > last_finish:
            last_finish = t
    hits = sum(1 for s in sojourns if s <= cfg["deadline_s"])
    n = len(sojourns)
    span = last_finish - arrivals[0]
    return {
        "deadline_hit_rate": hits / n if n else 1.0,
        "mean_sojourn_s": sum(sojourns) / n if n else 0.0,
        "p95_sojourn_s": fed_percentile(sojourns, 95.0),
        "throughput_rps": n / span if span > 0.0 else 0.0,
        "migrations": migrations,
        "spills": spills,
    }


def federation_frontier():
    """Mirror of serve::sim::simulate_federation_frontier on the
    FederationSimConfig::stub_fixture() constants. Load multiples are
    relative to ONE node's capacity (the no-tier baseline's ceiling);
    tests/integration_federation.rs pins this output against the
    in-process Rust sweep."""
    cfg = FED_CFG
    cap = cfg["servers_per_node"] / cfg["service_s"]
    traces = []
    for trace in FED_TRACES:
        points = []
        for load_x in cfg["load_multiples"]:
            rate = load_x * cap
            arr = fed_arrivals(trace, rate, cfg["n_requests"])
            points.append(
                {
                    "load_x": load_x,
                    "rate_rps": rate,
                    "single": fed_run(cfg, arr, "single"),
                    "fed_nomig": fed_run(cfg, arr, "fed_nomig"),
                    "fed_mig": fed_run(cfg, arr, "fed_mig"),
                }
            )
        traces.append({"trace": trace, "points": points})
    return traces


# --- graceful-degradation DES (serve/sim.rs degradation mirror) -------
DEG_CFG = {
    "servers": 3,
    "service_s": 1.0,
    "deadline_s": 3.0,
    "pressure_thresholds": [0.8, 1.6],
    "floor": "draft",
    "queue_capacity": 6,
    "brownout_speed": 0.25,
    "window_s": 5.0,
    "n_requests": 240,
    "load_multiples": [1.0, 1.5, 2.0, 2.5, 3.0],
}

DEG_PRICE_SLACK = 1.2
DEG_FACTOR = {"draft": 0.5, "standard": 1.0, "high": 1.5}
DEG_RANK = {"draft": 0, "standard": 1, "high": 2}
DEG_DEMOTE = {"high": "standard", "standard": "draft", "draft": "draft"}


def deg_tier(i):
    """Mirror of serve::sim::degrade_tier (high/standard/draft cycle)."""
    return ("high", "standard", "draft")[i % 3]


def deg_speed(cfg, server, t):
    """Rotating brownout: floor(t / window) % servers runs slowed."""
    if math.floor(t / cfg["window_s"]) % cfg["servers"] == server:
        return cfg["brownout_speed"]
    return 1.0


def deg_pressure(backlog, capacity, predicted, budget):
    """Mirror of serve::degrade::pressure_signal (match-arm order
    preserved: a positive budget with a finite prediction prices the
    deficit; an expired budget is a capped one-rung deficit)."""
    queue = backlog / capacity if capacity else 0.0
    if predicted is not None and budget is not None and budget > 0.0 \
            and math.isfinite(predicted):
        deficit = max((predicted - budget) / budget, 0.0)
    elif budget is not None and budget <= 0.0:
        deficit = 1.0
    else:
        deficit = 0.0
    return queue + deficit


def deg_rungs(pressure, thresholds):
    return sum(1 for t in thresholds if pressure >= t)


def deg_admission(quality, pressure, cfg, budget, predict):
    """Mirror of serve::degrade::admission_demotion (enabled=true)."""
    q = quality
    for _ in range(deg_rungs(pressure, cfg["pressure_thresholds"])):
        if DEG_RANK[q] <= DEG_RANK[cfg["floor"]]:
            break
        p = predict(q)
        if budget is not None and p is not None \
                and p * DEG_PRICE_SLACK <= budget:
            break
        q = DEG_DEMOTE[q]
    return q


def deg_run(cfg, arrivals, ladder_on):
    """Mirror of serve::sim::degrade_run, operation for operation.

    Greedy FIFO onto the earliest-free server; two step-halves whose
    durations follow the server's live speed sampled at each half's
    start. The ON side walks the real admission ladder and, past the
    top threshold, halves the remaining step work at the barrier when
    the priced second half would blow the deadline (floor-gated)."""
    free = [0.0] * cfg["servers"]
    finishes = []
    sojourns = []
    demoted = requantized = 0
    tier_sum = 0.0
    min_tier = None
    last_finish = 0.0
    for i, a in enumerate(arrivals):
        q = deg_tier(i)
        k, f0 = 0, free[0]
        for j, f in enumerate(free):
            if f < f0:
                k, f0 = j, f
        start = max(a, f0)
        budget = cfg["deadline_s"] - (start - a)
        backlog = sum(1 for f in finishes if f > a)
        if ladder_on:
            spd = deg_speed(cfg, k, start)

            def predict(qq):
                return cfg["service_s"] * DEG_FACTOR[qq] / spd

            p = deg_pressure(
                backlog, cfg["queue_capacity"], predict(q), budget
            )
            nq = deg_admission(q, p, cfg, budget, predict)
            if nq != q:
                demoted += 1
                q = nq
        work = cfg["service_s"] * DEG_FACTOR[q]
        t = start + 0.5 * work / deg_speed(cfg, k, start)
        rest = 0.5 * work
        if ladder_on and DEG_RANK[q] > DEG_RANK[cfg["floor"]]:
            pred = rest / deg_speed(cfg, k, t)
            rem_budget = a + cfg["deadline_s"] - t
            arrived = sum(1 for x in arrivals if x <= t)
            done = sum(1 for f in finishes if f <= t)
            backlog_mid = max(arrived - (done + 1), 0)
            p = deg_pressure(
                backlog_mid, cfg["queue_capacity"], pred, rem_budget
            )
            if cfg["pressure_thresholds"] \
                    and p >= cfg["pressure_thresholds"][-1] \
                    and pred * DEG_PRICE_SLACK > rem_budget:
                rest *= 0.5
                requantized += 1
        t += rest / deg_speed(cfg, k, t)
        free[k] = t
        finishes.append(t)
        sojourns.append(t - a)
        tier_sum += DEG_RANK[q]
        if min_tier is None or DEG_RANK[q] < min_tier:
            min_tier = DEG_RANK[q]
        if t > last_finish:
            last_finish = t
    n = len(sojourns)
    hits = sum(1 for s in sojourns if s <= cfg["deadline_s"])
    span = last_finish - arrivals[0]
    return {
        "deadline_hit_rate": hits / n if n else 1.0,
        "mean_sojourn_s": sum(sojourns) / n if n else 0.0,
        "p95_sojourn_s": fed_percentile(sojourns, 95.0),
        "throughput_rps": n / span if span > 0.0 else 0.0,
        "demoted": demoted,
        "requantized": requantized,
        "mean_tier": tier_sum / n if n else 0.0,
        "min_tier": min_tier if min_tier is not None else 0,
    }


def degradation_frontier():
    """Mirror of serve::sim::simulate_degradation_frontier on the
    DegradeSimConfig::stub_fixture() constants: the same steady
    arrival train replayed with the quality ladder OFF and ON;
    tests/integration_degrade.rs pins this output against the
    in-process Rust sweep."""
    cfg = DEG_CFG
    cap = cfg["servers"] / cfg["service_s"]
    points = []
    for load_x in cfg["load_multiples"]:
        rate = load_x * cap
        arr = [i / rate for i in range(cfg["n_requests"])]
        points.append(
            {
                "load_x": load_x,
                "rate_rps": rate,
                "off": deg_run(cfg, arr, False),
                "on": deg_run(cfg, arr, True),
            }
        )
    return points


SOURCE = (
    "scripts/gen_bench_artifacts.py — deterministic mirror of the "
    "timeline/comm/planner arithmetic (uncalibrated cost model, stub "
    "geometry). cargo bench writes measured sweeps to bench_out/."
)

# --- wire-protocol parse cost model (benches/bench_protocol.rs mirror)
# Relative per-operation costs of the two parse paths, in abstract
# units. The full tree parse scans every byte, allocates one Value
# node per JSON value, pushes one key entry per object member, and
# copies every string (keys and values) into the tree. The lazy
# scanner (serve/protocol.rs fast_scan) scans every byte in place,
# pays a constant dispatch cost per field, and materializes exactly
# one string: the request id. The constants weigh an allocation/copy
# against a byte scan; bench_protocol.rs recomputes this same model
# inline and cross-checks it against measured wall clock (warn-only —
# wall clock is machine-dependent, the committed artifact is not).
PROTO_SCAN_PER_BYTE = 1
PROTO_TREE_NODE = 60
PROTO_TREE_KEY = 40
PROTO_STRING_COPY_PER_BYTE = 2
PROTO_LAZY_FIELD = 6

# Canonical request lines — keep byte-identical to the constants in
# benches/bench_protocol.rs.
PROTO_V2_LINE = (
    '{"id":"req-000123","spec":{"seed":123456789,"steps":28,'
    '"height":256,"width":256,"quality":"standard",'
    '"priority":"normal","deadline_s":2.5}}'
)
PROTO_V1_LINE = '{"id":"req-000123","seed":123456789}'


def proto_counts(line):
    """(value nodes, object keys, copied string bytes) of the tree."""
    nodes = keys = sbytes = 0

    def walk(x):
        nonlocal nodes, keys, sbytes
        nodes += 1
        if isinstance(x, dict):
            for k, v in x.items():
                keys += 1
                sbytes += len(k.encode())
                walk(v)
        elif isinstance(x, list):
            for v in x:
                walk(v)
        elif isinstance(x, str):
            sbytes += len(x.encode())

    walk(json.loads(line))
    return nodes, keys, sbytes


def proto_entry(line):
    nodes, keys, sbytes = proto_counts(line)
    id_bytes = len(json.loads(line)["id"].encode())
    nbytes = len(line.encode())
    full = (
        nbytes * PROTO_SCAN_PER_BYTE
        + nodes * PROTO_TREE_NODE
        + keys * PROTO_TREE_KEY
        + sbytes * PROTO_STRING_COPY_PER_BYTE
    )
    # The scanner visits each key once (keys == fields walked) and
    # copies only the id.
    lazy = (
        nbytes * PROTO_SCAN_PER_BYTE
        + keys * PROTO_LAZY_FIELD
        + id_bytes * PROTO_STRING_COPY_PER_BYTE
    )
    return {
        "line": line,
        "bytes": nbytes,
        "tree_nodes": nodes,
        "tree_keys": keys,
        "tree_string_bytes": sbytes,
        "lazy_fields": keys,
        "lazy_copied_bytes": id_bytes,
        "full_cost_units": full,
        "lazy_cost_units": lazy,
        "speedup_lazy_vs_full": full / lazy,
    }


def protocol_bench():
    return {
        "bench": "protocol_lazy_parse",
        "source": SOURCE,
        "halo": "none (wire protocol only)",
        "cost_model": {
            "scan_per_byte": PROTO_SCAN_PER_BYTE,
            "tree_node": PROTO_TREE_NODE,
            "tree_key": PROTO_TREE_KEY,
            "string_copy_per_byte": PROTO_STRING_COPY_PER_BYTE,
            "lazy_field": PROTO_LAZY_FIELD,
        },
        "lines": {
            "v2": proto_entry(PROTO_V2_LINE),
            "v1": proto_entry(PROTO_V1_LINE),
        },
    }


def halo_entry(sync, disp, mode="displaced:1"):
    return {
        "mode": mode,
        "sync_total_s": sync["total_s"],
        "displaced_total_s": disp["total_s"],
        "speedup_vs_sync": sync["total_s"] / disp["total_s"],
    }


def main():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out_dir = root
    argv = sys.argv[1:]
    if argv and argv[0] == "--out":
        if len(argv) != 2:
            raise SystemExit("usage: gen_bench_artifacts.py [--out DIR]")
        out_dir = argv[1]
        os.makedirs(out_dir, exist_ok=True)
    elif argv:
        raise SystemExit("usage: gen_bench_artifacts.py [--out DIR]")

    # --- BENCH_serving: the paper testbed plan, sync vs displaced ----
    speeds = [1.0, 0.5]
    sync = plan_and_simulate(speeds, speeds, 100, 4, 32, DEFAULT_COMM)
    disp = plan_and_simulate(speeds, speeds, 100, 4, 32, DEFAULT_COMM, 1)
    slow_sync = plan_and_simulate(speeds, speeds, 100, 4, 32, SLOW_COMM)
    slow_disp = plan_and_simulate(speeds, speeds, 100, 4, 32, SLOW_COMM, 1)
    serving = {
        "bench": "serving_mixed_workload",
        "source": SOURCE,
        "occupancy": [0.0, 0.5],
        "service_stadi_sync_s": sync["total_s"],
        "rows": sync["rows"],
        "halo": halo_entry(sync, disp),
        "halo_slow_interconnect": halo_entry(slow_sync, slow_disp),
    }

    # --- BENCH_multires: per-size pricing, sync vs displaced ---------
    sizes = []
    prev = 0.0
    for name, rows in [("interactive", 16), ("native", 32), ("hires", 48)]:
        s = plan_and_simulate(speeds, speeds, 8, 2, rows, DEFAULT_COMM)
        d = plan_and_simulate(speeds, speeds, 8, 2, rows, DEFAULT_COMM, 1)
        assert s["total_s"] > prev, "size pricing must be monotone"
        assert d["total_s"] <= s["total_s"] + 1e-12
        prev = s["total_s"]
        sizes.append(
            {
                "class": name,
                "latent_rows": rows,
                "rows_split": s["rows"],
                "sync_total_s": s["total_s"],
                "displaced_total_s": d["total_s"],
            }
        )
    multires = {
        "bench": "serving_mixed_resolution",
        "source": SOURCE,
        "sizes": sizes,
        "halo": halo_entry(
            {"total_s": sizes[1]["sync_total_s"]},
            {"total_s": sizes[1]["displaced_total_s"]},
        ),
    }

    # --- BENCH_dynamic_occupancy: static plan under an occ ramp ------
    n_req = 12
    static_speeds = [1.0, 1.0]
    assign = assign_steps(static_speeds, 100, 4)
    rows = mend_rows(static_speeds, assign, 32)
    iv = intervals_for(assign, 100, 4)
    ramp = []
    for k in range(n_req):
        occ = 0.6 * k / (n_req - 1)
        eff = [1.0, 1.0 - occ]
        t = simulate(rows, eff, iv, DEFAULT_COMM)
        ramp.append(
            {"req": k, "occ_gpu1": occ, "static_s": t["total_s"]}
        )
    eff_drifted = [1.0, 0.4]
    h_sync = simulate(rows, eff_drifted, iv, DEFAULT_COMM)
    h_disp = simulate(rows, eff_drifted, iv, DEFAULT_COMM, 1)
    dyn = {
        "bench": "dynamic_occupancy",
        "source": SOURCE,
        "ramp": ramp,
        "halo": {**halo_entry(h_sync, h_disp), "occ_gpu1": 0.6},
    }

    # --- BENCH_halo: micro cost model + makespan sweep per budget ----
    micro = []
    for r0, r1 in [(16, 16), (24, 8), (28, 4)]:
        xs = [x_bytes(r0), x_bytes(r1)]
        micro.append(
            {
                "split": f"{r0}:{r1}",
                "x_bytes": xs,
                "blocking_gather_s": all_gather(SLOW_COMM, xs),
                "displaced_exchange_s": displaced_exchange(SLOW_COMM, xs),
            }
        )
    hs = plan_and_simulate(speeds, speeds, 16, 2, 32, SLOW_COMM)
    assert hs["comm_s"] > 0.2 * hs["total_s"], "fixture not comm-bound"
    # Not monotone in the budget: budget b forces the first b sync
    # points to fall back, so larger budgets pay a longer synchronous
    # prefix; every budget >= 1 must still strictly beat sync here.
    sweep = []
    for budget in range(4):
        t = plan_and_simulate(speeds, speeds, 16, 2, 32, SLOW_COMM, budget)
        if budget == 0:
            assert t["total_s"] == hs["total_s"], "budget 0 must be sync"
        else:
            assert t["total_s"] < hs["total_s"], "displaced must win"
        sweep.append(
            {
                "budget": budget,
                "total_s": t["total_s"],
                "comm_s": t["comm_s"],
                "displaced": t["displaced"],
                "fallback": t["fallback"],
                "speedup_vs_sync": hs["total_s"] / t["total_s"],
            }
        )
    halo_bench = {
        "bench": "halo_exchange",
        "source": SOURCE,
        "micro_cost_model": micro,
        "halo": {
            "latency_s": SLOW_COMM["latency_s"],
            "bandwidth_bytes_per_s": SLOW_COMM["bandwidth_bytes_per_s"],
            "occupancy": [0.0, 0.5],
            "rows": hs["rows"],
            "sync_points": hs["sync_points"],
            "sync_total_s": hs["total_s"],
            "sync_comm_s": hs["comm_s"],
            "sweep": sweep,
        },
    }

    # --- BENCH_federation: multi-node tier + migration frontier ------
    fed_traces = federation_frontier()
    for tr in fed_traces:
        for pt in tr["points"]:
            if pt["load_x"] < 2.0:
                continue
            assert (
                pt["fed_mig"]["deadline_hit_rate"]
                > pt["fed_nomig"]["deadline_hit_rate"]
            ), f'{tr["trace"]} x{pt["load_x"]}: migration must win'
            assert (
                pt["fed_nomig"]["deadline_hit_rate"]
                > pt["single"]["deadline_hit_rate"]
            ), f'{tr["trace"]} x{pt["load_x"]}: federation must win'
            assert pt["fed_mig"]["migrations"] > 0
    federation = {
        "bench": "federation",
        "source": "scripts/gen_bench_artifacts.py",
        "halo": "checkpoint-migration",
        "config": FED_CFG,
        "traces": fed_traces,
    }

    # --- BENCH_batching: fused sessions vs disjoint leases frontier --
    frontier = batch_frontier()
    for pt in frontier["points"]:
        if pt["load_x"] >= 2.0:
            assert (
                pt["batched"]["throughput_rps"]
                > pt["disjoint"]["throughput_rps"]
            ), "batched must strictly beat disjoint from 2x load"
            assert (
                pt["batched"]["deadline_hit_rate"]
                >= pt["disjoint"]["deadline_hit_rate"]
            ), "batched deadline hits must not regress"
    batching = {
        "bench": "batching",
        "source": SOURCE,
        "frontier": frontier,
    }

    # --- BENCH_degradation: quality ladder under overload ------------
    deg_points = degradation_frontier()
    deg_requant_total = 0
    for pt in deg_points:
        assert pt["off"]["demoted"] == 0
        assert pt["off"]["requantized"] == 0
        assert pt["on"]["min_tier"] >= DEG_RANK[DEG_CFG["floor"]], (
            f'x{pt["load_x"]}: served below the floor'
        )
        deg_requant_total += pt["on"]["requantized"]
        if pt["load_x"] >= 2.0:
            assert (
                pt["on"]["deadline_hit_rate"]
                > pt["off"]["deadline_hit_rate"]
            ), f'x{pt["load_x"]}: ladder must beat shedding'
            assert pt["on"]["demoted"] > 0, (
                f'x{pt["load_x"]}: the winning side must demote'
            )
    assert deg_requant_total > 0, "top rung never fired in the sweep"
    degradation = {
        "bench": "degradation",
        "source": "scripts/gen_bench_artifacts.py",
        "halo": "quality-ladder",
        "config": DEG_CFG,
        "points": deg_points,
    }

    # --- BENCH_protocol: lazy vs full-tree wire parse cost model -----
    protocol = protocol_bench()
    assert (
        protocol["lines"]["v2"]["speedup_lazy_vs_full"] >= 5.0
    ), "lazy parse must model >= 5x over the full tree on the v2 line"

    for name, obj in [
        ("BENCH_serving.json", serving),
        ("BENCH_multires.json", multires),
        ("BENCH_dynamic_occupancy.json", dyn),
        ("BENCH_halo.json", halo_bench),
        ("BENCH_batching.json", batching),
        ("BENCH_federation.json", federation),
        ("BENCH_degradation.json", degradation),
        ("BENCH_protocol.json", protocol),
    ]:
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            json.dump(obj, f, indent=2)
            f.write("\n")
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
